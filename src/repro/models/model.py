"""Model dispatch: init/abstract params, train/prefill/serve step builders.

This is the public API surface used by tests, examples, benchmarks, and the
launchers. Family routing:

  dense | moe | ssm | hybrid | vlm  -> models.transformer
  audio                              -> models.whisper (enc-dec)
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import transformer, whisper
from .transformer import DistContext


def _mod(cfg: ModelConfig):
    return whisper if cfg.family == "audio" else transformer


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_params_and_axes(rng, cfg: ModelConfig, dtype=jnp.float32):
    """Returns (params, axes) trees. dtype applied to all floating leaves."""
    tree = _mod(cfg).make_model_params(rng, cfg)
    params, axes = L.split_params(tree)
    if dtype != jnp.float32:
        params = jax.tree.map(lambda a: a.astype(dtype), params)
    return params, axes


def init_params(rng, cfg: ModelConfig, dtype=jnp.float32):
    return init_params_and_axes(rng, cfg, dtype)[0]


def abstract_params_and_axes(cfg: ModelConfig, dtype=jnp.float32):
    """ShapeDtypeStruct params (no allocation) + axes tree, for dry-runs.

    Param's axes ride in the treedef (aux data), so eval_shape of the Param
    tree preserves them without materializing anything."""
    tree = jax.eval_shape(lambda k: _mod(cfg).make_model_params(k, cfg),
                          jax.random.PRNGKey(0))
    params, axes = L.split_params(tree)
    if dtype != jnp.float32:
        params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype), params)
    return params, axes


def transform_params_for_dualsparse(params, cfg: ModelConfig, calib_x,
                                    n_ep_devices: int = 0,
                                    target_drop_rate: Optional[float] = None):
    """DEPRECATED shim over the ``SparsityPolicy`` API: equivalent to
    ``make_policy("2t" | "per_layer", cfg.dualsparse).prepare(...)[0]``.
    Prefer building a policy (``repro.core.policy``) and calling its
    ``prepare`` — that also returns the calibrated policy object that the
    rest of the stack (DistContext, engines, CLI) consumes."""
    import warnings
    warnings.warn(
        "transform_params_for_dualsparse is deprecated; build a policy via "
        "repro.core.policy.make_policy and call policy.prepare(...) instead",
        DeprecationWarning, stacklevel=2)
    from ..core.policy import make_policy
    ds = cfg.dualsparse
    if not (cfg.is_moe and ds.enabled):
        return params
    name = "per_layer" if target_drop_rate is not None else "2t"
    pol = make_policy(name, ds, drop_target=target_drop_rate)
    return pol.prepare(params, cfg, calib_x, n_ep_devices=n_ep_devices)[0]


# ---------------------------------------------------------------------------
# Loss / steps
# ---------------------------------------------------------------------------

def cross_entropy(logits, targets):
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def loss_fn(params, batch, cfg: ModelConfig, *, window: int = 0,
            dist: Optional[DistContext] = None, aux_coef: float = 0.0):
    """Cross entropy (+ Switch-style MoE load-balance aux when aux_coef>0)."""
    if aux_coef and cfg.is_moe and cfg.family != "audio":
        logits, aux = _mod(cfg).forward(params, batch, cfg, window=window,
                                        dist=dist, with_aux=True)
        return cross_entropy(logits, batch["targets"]) + aux_coef * aux
    logits = _mod(cfg).forward(params, batch, cfg, window=window, dist=dist)
    return cross_entropy(logits, batch["targets"])


def make_train_step(cfg: ModelConfig, optimizer, *, window: int = 0,
                    dist: Optional[DistContext] = None,
                    aux_coef: float = 0.0):
    """(params, opt_state, batch) -> (params, opt_state, loss)."""
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg,
                                                  window=window, dist=dist,
                                                  aux_coef=aux_coef)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        return params, opt_state, loss
    return step


def make_prefill_step(cfg: ModelConfig, *, cache_len: int = 0, window: int = 0,
                      dist: Optional[DistContext] = None,
                      cache_dtype=None, metrics: bool = True):
    """batch -> (logits (B,S,vocab), populated decode cache)."""
    import jax.numpy as _jnp
    cd = cache_dtype if cache_dtype is not None else _jnp.bfloat16
    # whisper (audio) caches have no MoE metrics seam
    kw = {} if cfg.family == "audio" else {"metrics": metrics}
    def step(params, batch):
        return _mod(cfg).prefill(params, batch, cfg, cache_len=cache_len,
                                 window=window, dist=dist, cache_dtype=cd,
                                 **kw)
    return step


def make_serve_step(cfg: ModelConfig, *, window: int = 0,
                    dist: Optional[DistContext] = None):
    """(params, token (B,1), cache) -> (logits, cache) — ONE new token."""
    def step(params, token, cache):
        return _mod(cfg).decode_step(params, token, cache, cfg,
                                     window=window, dist=dist)
    return step


def context_len_for(cfg: ModelConfig, prompt_len: int, new_tokens: int) -> int:
    """KV capacity needed to prefill ``prompt_len`` tokens (plus any stub
    frontend prefix) and then generate ``new_tokens``."""
    prefix = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    return prompt_len + prefix + new_tokens


def init_cache(cfg: ModelConfig, batch: int, context_len: int, *,
               window: int = 0, dtype=jnp.bfloat16,
               per_slot_pos: bool = False, metrics_spec=None):
    kw: Dict[str, Any] = {}
    if cfg.family != "audio":
        kw["metrics_spec"] = metrics_spec
    if per_slot_pos:
        return _mod(cfg).init_cache(cfg, batch, context_len, window=window,
                                    dtype=dtype, per_slot_pos=True, **kw)
    return _mod(cfg).init_cache(cfg, batch, context_len, window=window,
                                dtype=dtype, **kw)


def abstract_cache(cfg: ModelConfig, batch: int, context_len: int, *,
                   window: int = 0, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, context_len, window=window,
                           dtype=dtype))


# ---------------------------------------------------------------------------
# Input construction (concrete); abstract variants live in launch.dryrun
# ---------------------------------------------------------------------------

def make_batch(rng, cfg: ModelConfig, batch: int, seq: int, kind: str,
               dtype=jnp.float32):
    """Concrete random batch for smoke tests / examples."""
    ks = jax.random.split(rng, 3)
    out: Dict[str, Any] = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size),
    }
    if kind == "train":
        out["targets"] = jax.random.randint(ks[1], (batch, seq), 0,
                                            cfg.vocab_size)
    if cfg.frontend == "vision":
        out["frontend"] = jax.random.normal(
            ks[2], (batch, cfg.n_frontend_tokens, cfg.d_model), dtype) * 0.1
    if cfg.frontend == "audio":
        out["audio_embeds"] = jax.random.normal(
            ks[2], (batch, cfg.n_frontend_tokens, cfg.d_model), dtype) * 0.1
    return out
