"""Mamba2 / SSD (state-space duality) layer [arXiv:2405.21060].

TPU adaptation: the sequence dimension is processed in the *chunked matmul
form* of SSD — intra-chunk terms are batched (Q×Q) matmuls that map onto the
MXU, and the inter-chunk state recurrence is a ``jax.lax.associative_scan``
over chunks (log-depth, collective-free). No sequential per-token scan is
ever lowered for training/prefill; decode is the O(1) state update.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from .layers import Param, normal, zeros, ones


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def make_mamba2_params(key, cfg):
    d = cfg.d_model
    di = cfg.d_inner
    G, N = cfg.ssm_n_groups, cfg.ssm_state
    H = cfg.ssm_heads
    conv_ch = di + 2 * G * N
    ks = jax.random.split(key, 6)
    return {
        "in_proj": normal(ks[0], (d, 2 * di + 2 * G * N + H), ("embed", "ssm_inner")),
        "conv_w": normal(ks[1], (cfg.ssm_conv_width, conv_ch), (None, "ssm_inner"), scale=0.1),
        "conv_b": zeros((conv_ch,), ("ssm_inner",)),
        "dt_bias": Param(jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,)) *
                    (np.log(0.1) - np.log(0.001)) + np.log(0.001)))),
            ("ssm_heads",)),
        "A_log": Param(jnp.log(jax.random.uniform(ks[3], (H,), minval=1.0, maxval=16.0)),
                       ("ssm_heads",)),
        "D": ones((H,), ("ssm_heads",)),
        "norm": ones((di,), ("ssm_inner",)),
        "out_proj": normal(ks[4], (di, d), ("ssm_inner", "embed")),
    }


def _split_in_proj(cfg, zxbcdt):
    di, G, N, H = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_heads
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    return z, xbc, dt


def _split_xbc(cfg, xbc):
    di, G, N = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state
    x, B, C = jnp.split(xbc, [di, di + G * N], axis=-1)
    return x, B, C


# ---------------------------------------------------------------------------
# Chunked SSD core
# ---------------------------------------------------------------------------

def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) with out[i,j] = sum_{j<k<=i} x[k], -inf above diag."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = np.tril(np.ones((Q, Q), bool), 0)
    return jnp.where(mask, out, -np.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int = 256):
    """SSD in chunked (matmul) form.

    x: (b, S, H, P); dt: (b, S, H) (already softplus'd, >0); A: (H,) (<0)
    B, C: (b, S, G, N) with H divisible by G.
    Returns y: (b, S, H, P) and final state (b, H, P, N).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    rep = H // G

    # reshape into chunks
    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = B.reshape(b, nc, chunk, G, N)
    Cc = C.reshape(b, nc, chunk, G, N)
    Bh = jnp.repeat(Bc, rep, axis=3)            # (b,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A[None, None, None, :]           # (b,nc,Q,H) decay logs (<0)
    dA_cum = jnp.cumsum(dA, axis=2)             # within-chunk cumulative

    # 1) intra-chunk (quadratic within chunk, matmul form)
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))           # (b,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)        # (b,nc,H,Q,Q)
    M = scores * L
    y_intra = jnp.einsum("bchqk,bckh,bckhp->bcqhp", M, dtc, xc)

    # 2) chunk states: contribution of each chunk to the running state
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)    # (b,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqh,bcqhp->bchpn",
                        Bh, dtc, decay_to_end, xc)           # (b,nc,H,P,N)

    # 3) inter-chunk recurrence h_c = a_c * h_{c-1} + states_c  (associative)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])               # (b,nc,H)

    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, s1 * a2[..., None, None] + s2

    a_all, h_all = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1)
    # state entering chunk c is h_{c-1}
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_all[:, :1]), h_all[:, :-1]], axis=1)

    # 4) inter-chunk output
    in_decay = jnp.exp(dA_cum)                               # (b,nc,Q,H)
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", Ch, in_decay, h_prev)

    y = (y_intra + y_inter).reshape(b, Sp, H, P)[:, :S]
    return y, h_all[:, -1]


def ssd_chunked_kernel(x, dt, A, B, C, chunk: int = 128,
                       interpret: bool = True):
    """ssd_chunked with the intra-chunk hot spot executed by the Pallas
    kernel (kernels/ssd_chunk.py); recurrence + inter-chunk term in JAX.
    Same signature/semantics as ssd_chunked."""
    from ..kernels.ssd_chunk import ssd_chunk_pallas
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    rep = H // G
    # (b,S,H,*) -> (b*H, nc, Q, *)
    xk = x.transpose(0, 2, 1, 3).reshape(b * H, nc, chunk, P)
    dtk = dt.transpose(0, 2, 1).reshape(b * H, nc, chunk)
    Bh = jnp.repeat(B, rep, axis=2).transpose(0, 2, 1, 3)
    Ch = jnp.repeat(C, rep, axis=2).transpose(0, 2, 1, 3)
    Bk = Bh.reshape(b * H, nc, chunk, N)
    Ck = Ch.reshape(b * H, nc, chunk, N)
    ak = jnp.tile(A, b)

    y_intra, states, chunk_decay = ssd_chunk_pallas(
        xk, dtk, ak, Bk, Ck, interpret=interpret)

    # inter-chunk recurrence (associative, log depth)
    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, s1 * a2[..., None, None] + s2

    a_all, h_all = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1)     # states: (BH,nc,N,P)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_all[:, :1]), h_all[:, :-1]], axis=1)

    # inter-chunk output (in-chunk decay recomputed: cheap elementwise)
    dA_cum = jnp.cumsum(dtk * ak[:, None, None], axis=-1)
    in_decay = jnp.exp(dA_cum)                       # (BH, nc, Q)
    y_inter = jnp.einsum("bcqn,bcq,bcnp->bcqp", Ck, in_decay, h_prev)

    y = (y_intra + y_inter).reshape(b, H, Sp, P).transpose(0, 2, 1, 3)[:, :S]
    h_final = jnp.swapaxes(h_all[:, -1], -1, -2).reshape(b, H, P, N)
    return y, h_final


def ssd_reference(x, dt, A, B, C):
    """Sequential-scan oracle for tests (O(S) steps)."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * A)[..., None, None]            # (b,H,1,1)
        dBx = jnp.einsum("bh,bhn,bhp->bhpn", dtt, Bt, xt)
        h = h * decay + dBx
        y = jnp.einsum("bhn,bhpn->bhp", Ct, h)
        return h, y

    h0 = jnp.zeros((b, H, P, N), x.dtype)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h


# ---------------------------------------------------------------------------
# Full block forward (train/prefill) and decode step
# ---------------------------------------------------------------------------

class MambaState(NamedTuple):
    conv: jax.Array    # (B, W-1, conv_ch) last inputs
    ssm: jax.Array     # (B, H, P, N)


def init_mamba_state(batch: int, cfg, dtype=jnp.float32) -> MambaState:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state
    return MambaState(
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        ssm=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                      dtype),
    )


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, xbc: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(W))
    return out + b


def mamba2_forward(params, x_in, cfg, chunk: int = 256,
                   return_state: bool = False):
    """x_in: (B,S,d_model) -> (B,S,d_model). Training/prefill path.
    With ``return_state`` also returns the decode state after the sequence
    (prefill -> decode handoff)."""
    B_, S, _ = x_in.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_n_groups, cfg.ssm_state
    z, xbc_raw, dt = _split_in_proj(cfg, x_in @ params["in_proj"])
    xbc = jax.nn.silu(_causal_conv(xbc_raw, params["conv_w"], params["conv_b"]))
    x, Bmat, Cmat = _split_xbc(cfg, xbc)
    x = x.reshape(B_, S, H, P)
    Bmat = Bmat.reshape(B_, S, G, N)
    Cmat = Cmat.reshape(B_, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, h_final = ssd_chunked(x.astype(jnp.float32), dt, A,
                             Bmat.astype(jnp.float32), Cmat.astype(jnp.float32),
                             chunk=chunk)
    y = y + x.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(B_, S, cfg.d_inner).astype(x_in.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if not return_state:
        return out
    # conv state: last (W-1) raw xbc inputs, left-padded for short sequences
    W = cfg.ssm_conv_width
    pad = jnp.pad(xbc_raw, ((0, 0), (W - 1, 0), (0, 0)))
    conv_state = pad[:, pad.shape[1] - (W - 1):, :].astype(jnp.float32)
    return out, {"conv": conv_state, "ssm": h_final}


def mamba2_decode(params, x_in, state: MambaState, cfg):
    """One-token decode: x_in (B,1,d) -> (out (B,1,d), new state)."""
    B_ = x_in.shape[0]
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_n_groups, cfg.ssm_state
    z, xbc, dt = _split_in_proj(cfg, x_in @ params["in_proj"])
    # conv over (state ++ current)
    win = jnp.concatenate([state.conv, xbc], axis=1)          # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", win, params["conv_w"]) + params["conv_b"]
    xbc_t = jax.nn.silu(conv_out)[:, None, :]
    new_conv = win[:, 1:, :]
    x, Bmat, Cmat = _split_xbc(cfg, xbc_t)
    x = x.reshape(B_, H, P)
    Bmat = jnp.repeat(Bmat.reshape(B_, G, N), H // G, axis=1)  # (B,H,N)
    Cmat = jnp.repeat(Cmat.reshape(B_, G, N), H // G, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)[..., None, None]
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt, Bmat.astype(jnp.float32),
                     x.astype(jnp.float32))
    h = state.ssm * decay + dBx
    y = jnp.einsum("bhn,bhpn->bhp", Cmat.astype(jnp.float32), h)
    y = y + x.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(B_, 1, cfg.d_inner).astype(x_in.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"], MambaState(new_conv, h)
