"""Shared neural-net building blocks and the tiny param system.

Params are plain pytrees (nested dicts of jnp arrays). Alongside each params
tree we build a *structurally identical* tree of logical-axis tuples (strings)
used by ``repro.distributed.sharding`` to derive PartitionSpecs. The two trees
are built in one pass via ``Param`` leaves and split with ``split_params``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Param system
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Param:
    """A leaf holding both the value and its logical sharding axes."""
    value: Any                   # jnp array (or ShapeDtypeStruct under eval_shape)
    axes: Tuple[Optional[str], ...]


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.axes),
    lambda axes, vals: Param(vals[0], axes),
)


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree):
    """(params_tree, axes_tree) from a tree with Param leaves."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def normal(key, shape, axes, scale=0.02, dtype=jnp.float32) -> Param:
    return Param((scale * jax.random.normal(key, shape)).astype(dtype), axes)


def zeros(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


def ones(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


def stack_layer_params(key, n_layers: int, build_fn) -> Any:
    """vmap a per-layer param builder over a leading 'layers' axis (for scan)."""
    keys = jax.random.split(key, n_layers)
    stacked = jax.vmap(build_fn)(keys)
    # prepend the (unsharded) layers axis to every leaf's logical axes
    return jax.tree.map(
        lambda p: Param(p.value, ("layers",) + tuple(p.axes)),
        stacked, is_leaf=is_param)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4,
               mrope_sections: Sequence[int] = ()):
    """Rotate-half rotary embedding.

    x: (..., S, H, D). positions: (B, S) int32 — or (3, B, S) for M-RoPE,
    in which case ``mrope_sections`` (summing to D//2) selects which position
    stream each frequency index uses (Qwen2-VL §2).
    """
    d = x.shape[-1]
    inv = jnp.asarray(rope_freqs(d, theta))                 # (D/2,)
    if mrope_sections:
        assert positions.ndim == 3, "M-RoPE needs (3,B,S) positions"
        # angle per stream: (3, B, S, D/2)
        ang3 = positions[..., None].astype(jnp.float32) * inv
        sec_ids = np.repeat(np.arange(len(mrope_sections)),
                            list(mrope_sections))            # (D/2,) in [0,3)
        sel = jnp.asarray(sec_ids[None, :] ==
                          np.arange(len(mrope_sections))[:, None],
                          dtype=jnp.float32)                 # (3, D/2)
        ang = jnp.einsum("kbsd,kd->bsd", ang3, sel)
    else:
        ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, D/2)
    cos = jnp.cos(ang)[..., None, :]                           # (B,S,1,D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x, w1, w3, w2):
    """SwiGLU FFN (paper Eq. 4): (Swish(x·W1) ⊙ (x·W3)) · W2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def gelu_mlp(x, w_in, w_out):
    return jax.nn.gelu(x @ w_in, approximate=True) @ w_out


def make_mlp_params(key, d_model: int, d_ff: int, kind: str):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w1": normal(k1, (d_model, d_ff), ("embed", "ffn")),
            "w3": normal(k2, (d_model, d_ff), ("embed", "ffn")),
            "w2": normal(k3, (d_ff, d_model), ("ffn", "embed"), scale=0.02),
        }
    return {
        "w_in": normal(k1, (d_model, d_ff), ("embed", "ffn")),
        "w_out": normal(k2, (d_ff, d_model), ("ffn", "embed")),
    }


def apply_mlp(params, x, kind: str):
    if kind == "swiglu":
        return swiglu(x, params["w1"], params["w3"], params["w2"])
    return gelu_mlp(x, params["w_in"], params["w_out"])


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def make_embed_params(key, vocab: int, d_model: int, tie: bool):
    k1, k2 = jax.random.split(key)
    p = {"embedding": normal(k1, (vocab, d_model), ("vocab", "embed"))}
    if not tie:
        p["lm_head"] = normal(k2, (d_model, vocab), ("embed", "vocab"))
    return p


def embed(params, tokens):
    return params["embedding"][tokens]


def unembed(params, x):
    if "lm_head" in params:
        return x @ params["lm_head"]
    return x @ params["embedding"].T
