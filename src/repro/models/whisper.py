"""Whisper-style encoder-decoder backbone (audio frontend is a STUB).

input_specs provide precomputed frame embeddings (B, n_frames, d_model) in
place of the mel+conv frontend (the assignment's one allowed stub). The
encoder is a non-causal transformer over frames; the decoder is causal with
cross-attention to the encoder output. Layers scan over stacked params.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from . import layers as L
from .layers import normal, ones


def _sinusoid(n: int, d: int):
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], -1),
                       jnp.float32)


def make_enc_block(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": ones((cfg.d_model,), ("embed",)),
        "attn": attn.make_gqa_params(ks[0], cfg),
        "ln2": ones((cfg.d_model,), ("embed",)),
        "mlp": L.make_mlp_params(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind),
    }


def make_dec_block(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": ones((cfg.d_model,), ("embed",)),
        "attn": attn.make_gqa_params(ks[0], cfg),
        "ln_x": ones((cfg.d_model,), ("embed",)),
        "xattn": attn.make_gqa_params(ks[1], cfg),
        "ln2": ones((cfg.d_model,), ("embed",)),
        "mlp": L.make_mlp_params(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_kind),
    }


def make_model_params(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": L.make_embed_params(k1, cfg.vocab_size, cfg.d_model,
                                     cfg.tie_embeddings),
        "frontend_proj": normal(k2, (cfg.d_model, cfg.d_model),
                                ("embed", None)),
        "encoder": L.stack_layer_params(k3, cfg.encoder_layers,
                                        lambda k: make_enc_block(k, cfg)),
        "enc_norm": ones((cfg.d_model,), ("embed",)),
        "decoder": L.stack_layer_params(k4, cfg.n_layers,
                                        lambda k: make_dec_block(k, cfg)),
        "final_norm": ones((cfg.d_model,), ("embed",)),
    }


def _self_attn_nocache(p, x, positions, cfg, causal, dist=None):
    q, k, v = attn.gqa_project_qkv(p, x, positions, cfg)
    if x.shape[1] > 1024:
        shard_blocks, qb = attn.make_shard_blocks(dist, x.shape[1])
        o = attn.blockwise_attention(q, k, v, causal=causal, q_block=qb,
                                     shard_blocks=shard_blocks)
    else:
        o = attn.plain_attention(q, k, v, causal=causal)
    return jnp.einsum("bshgk,hgkd->bsd", o, p["wo"])


def _cross_attn(p, x, enc_kv, cfg, dist=None):
    """x: (B,S,d) queries; enc_kv: (k, v) each (B, T, Hkv, D) (pre-projected,
    no RoPE — whisper uses absolute positions)."""
    q = jnp.einsum("bsd,dhgk->bshgk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    k, v = enc_kv
    if x.shape[1] > 1024:
        shard_blocks, qb = attn.make_shard_blocks(dist, x.shape[1])
        o = attn.blockwise_attention(q, k, v, causal=False, q_block=qb,
                                     shard_blocks=shard_blocks)
    else:
        o = attn.plain_attention(q, k, v, causal=False)
    return jnp.einsum("bshgk,hgkd->bsd", o, p["wo"])


def encode(params, audio_embeds, cfg, dist=None):
    """audio_embeds: (B, T, d) stub frontend output."""
    x = audio_embeds @ params["frontend_proj"]
    T = x.shape[1]
    x = x + _sinusoid(T, cfg.d_model).astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                           (x.shape[0], T))

    def block(h, bp):
        a = L.rms_norm(h, bp["ln1"], cfg.norm_eps)
        # encoder self-attention is non-causal over absolute-position embeds
        # (RoPE at position 0 is the identity)
        q, k, v = attn.gqa_project_qkv(bp["attn"], a, jnp.zeros_like(pos), cfg)
        fn = attn.blockwise_attention if h.shape[1] > 1024 else attn.plain_attention
        o = fn(q, k, v, causal=False)
        h = h + jnp.einsum("bshgk,hgkd->bsd", o, bp["attn"]["wo"])
        a = L.rms_norm(h, bp["ln2"], cfg.norm_eps)
        return h + L.apply_mlp(bp["mlp"], a, cfg.mlp_kind)

    if dist is not None and dist.remat:
        block = jax.checkpoint(block)

    def body(h, bp):
        return block(h, bp), None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _enc_kv(params, enc_out, cfg):
    """Pre-project encoder K/V for every decoder layer: (L,B,T,Hkv,D)×2."""
    def proj(bp):
        k = jnp.einsum("btd,dhk->bthk", enc_out, bp["xattn"]["wk"])
        v = jnp.einsum("btd,dhk->bthk", enc_out, bp["xattn"]["wv"])
        if "bk" in bp["xattn"]:
            k = k + bp["xattn"]["bk"]
            v = v + bp["xattn"]["bv"]
        return k, v
    return jax.vmap(proj)(params["decoder"])


def forward(params, batch, cfg, *, window: int = 0, dist=None):
    """Training/prefill: batch = {"tokens": (B,S), "audio_embeds": (B,T,d)}."""
    enc_out = encode(params, batch["audio_embeds"], cfg, dist=dist)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    x = x + _sinusoid(S, cfg.d_model).astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ck, cv = _enc_kv(params, enc_out, cfg)          # (L,B,T,H,D)

    def block(h, bp, k_l, v_l):
        a = L.rms_norm(h, bp["ln1"], cfg.norm_eps)
        h = h + _self_attn_nocache(bp["attn"], a, pos, cfg, causal=True,
                                   dist=dist)
        a = L.rms_norm(h, bp["ln_x"], cfg.norm_eps)
        h = h + _cross_attn(bp["xattn"], a, (k_l, v_l), cfg, dist=dist)
        a = L.rms_norm(h, bp["ln2"], cfg.norm_eps)
        return h + L.apply_mlp(bp["mlp"], a, cfg.mlp_kind)

    if dist is not None and dist.remat:
        block = jax.checkpoint(block)

    def body(h, xs):
        bp, k_l, v_l = xs
        return block(h, bp, k_l, v_l), None

    x, _ = jax.lax.scan(body, x, (params["decoder"], ck, cv))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(params["embed"], x)


def prefill(params, batch, cfg, *, cache_len: int = 0, window: int = 0,
            dist=None, cache_dtype=jnp.bfloat16):
    """Encoder pass + decoder pass over the prompt, returning logits AND a
    fully populated decode cache (self-attn K/V + cross K/V)."""
    enc_out = encode(params, batch["audio_embeds"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    cap = cache_len if cache_len else S
    if window:
        cap = min(cap, window)
    x = L.embed(params["embed"], tokens)
    x = x + _sinusoid(S, cfg.d_model).astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ck, cv = _enc_kv(params, enc_out, cfg)

    def body(h, xs):
        bp, k_l, v_l = xs
        a = L.rms_norm(h, bp["ln1"], cfg.norm_eps)
        y, cl = attn.gqa_prefill_attention(bp["attn"], a, pos, cfg,
                                           window=window, cap=cap,
                                           cache_dtype=cache_dtype,
                                           dist=dist)
        h = h + y
        a = L.rms_norm(h, bp["ln_x"], cfg.norm_eps)
        h = h + _cross_attn(bp["xattn"], a, (k_l, v_l), cfg, dist=dist)
        a = L.rms_norm(h, bp["ln2"], cfg.norm_eps)
        return h + L.apply_mlp(bp["mlp"], a, cfg.mlp_kind), cl

    x, self_caches = jax.lax.scan(body, x, (params["decoder"], ck, cv))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    cache = {"layers": self_caches,
             "cross_k": ck.astype(self_caches["k"].dtype),
             "cross_v": cv.astype(self_caches["v"].dtype),
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def init_cache(cfg, batch: int, context_len: int, *, window: int = 0,
               dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    cap = min(window, context_len) if window else context_len
    Lc = cfg.n_layers
    T = cfg.n_frontend_tokens
    return {
        "layers": {
            "k": jnp.zeros((Lc, batch, cap, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((Lc, batch, cap, cfg.n_kv_heads, hd), dtype),
        },
        "cross_k": jnp.zeros((Lc, batch, T, cfg.n_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((Lc, batch, T, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill_cache(params, batch, cfg, cache):
    """Populate cross K/V from the encoder (decode starts from pos 0)."""
    enc_out = encode(params, batch["audio_embeds"], cfg)
    ck, cv = _enc_kv(params, enc_out, cfg)
    cache = dict(cache)
    cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
    cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
    return cache


def decode_step(params, token, cache, cfg, *, window: int = 0, dist=None):
    pos = cache["pos"]
    B = token.shape[0]
    x = L.embed(params["embed"], token)
    # absolute sinusoidal position for the current step
    d = cfg.d_model
    i = np.arange(d // 2)
    ang = pos.astype(jnp.float32) / (10000 ** (2 * i / d))
    x = x + jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None].astype(x.dtype)

    def body(h, xs):
        bp, cl, ck_l, cv_l = xs
        a = L.rms_norm(h, bp["ln1"], cfg.norm_eps)
        y, cl = attn.gqa_decode_attention(bp["attn"], a, cl, pos, cfg, window)
        h = h + y
        a = L.rms_norm(h, bp["ln_x"], cfg.norm_eps)
        h = h + _cross_attn(bp["xattn"], a, (ck_l, cv_l), cfg)
        a = L.rms_norm(h, bp["ln2"], cfg.norm_eps)
        return h + L.apply_mlp(bp["mlp"], a, cfg.mlp_kind), cl

    x, new_layers = jax.lax.scan(
        body, x,
        (params["decoder"], cache["layers"], cache["cross_k"],
         cache["cross_v"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    return logits, {"layers": new_layers, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "pos": pos + 1}
