"""Attention substrate: blockwise (flash-style) attention in pure JAX,
GQA/MQA, MLA (latent attention) with absorbed decode, KV caches including
a ring-buffer sliding-window cache for sub-quadratic long-context decode.

No (S,S) score matrix is ever materialized for long sequences — the
blockwise path keeps activations at O(S * block) via an online-softmax scan,
which is the TPU-friendly structure (each block pair is an MXU matmul).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from .layers import normal, zeros, ones

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blockwise attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------

def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset=0, kv_valid_len=None,
                        q_block: int = 512, kv_block: int = 1024,
                        shard_blocks=None):
    """Online-softmax blockwise attention.

    q: (B, Sq, Hkv, G, D)   — query heads grouped under their KV head
    k, v: (B, Skv, Hkv, D)
    q_offset: absolute position of q[0] (int or traced scalar) for causal
      masking during decode/prefill continuation.
    window: if >0, query i attends keys j with i-window < j <= i.
    kv_valid_len: if given (scalar), keys >= this index are masked out.
    shard_blocks: optional fn(x, n_lead_batchlike) applying a sharding
      constraint with the q-block dim mapped to the model axis — context
      parallelism: each model shard owns a band of query blocks and scans
      the full KV (GQA models whose few KV heads cannot split over a large
      TP axis would otherwise leave it idle and invite bad propagation).
    Returns (B, Sq, Hkv, G, D).
    """
    B, Sq, H, G, D = q.shape
    Skv = k.shape[1]
    orig_sq = Sq
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    # pad to block multiples
    pq = (-Sq) % qb
    pk = (-Skv) % kb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        Sq += pq
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        if kv_valid_len is None:
            kv_valid_len = Skv
        Skv += pk
    nq, nk = Sq // qb, Skv // kb
    scale = 1.0 / np.sqrt(D)

    q = q.reshape(B, nq, qb, H, G, D)
    k = k.reshape(B, nk, kb, H, D)
    v = v.reshape(B, nk, kb, H, D)
    if shard_blocks is not None:
        q = shard_blocks(q)
        k = shard_blocks(k, model_dim=None)
        v = shard_blocks(v, model_dim=None)

    q_pos = q_offset + jnp.arange(Sq).reshape(nq, qb)          # (nq, qb)
    k_pos = jnp.arange(Skv).reshape(nk, kb)                     # (nk, kb)

    def per_q_block(q_blk, q_pos_blk):
        # q_blk: (B, qb, H, G, D); scan over kv blocks
        def step(carry, inp):
            m, l, o = carry
            k_blk, v_blk, k_pos_blk = inp
            s = jnp.einsum("bqhgd,bkhd->bqhgk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qb, kb), dtype=bool)
            if causal:
                mask &= q_pos_blk[:, None] >= k_pos_blk[None, :]
            if window:
                mask &= q_pos_blk[:, None] - k_pos_blk[None, :] < window
            if kv_valid_len is not None:
                mask &= (k_pos_blk < kv_valid_len)[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            o_new = o * corr[..., None] + pv
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, qb, H, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qb, H, G), jnp.float32)
        o0 = jnp.zeros((B, qb, H, G, D), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            step, (m0, l0, o0),
            (jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0), k_pos))
        return o / jnp.maximum(l[..., None], 1e-30)

    out = jax.vmap(per_q_block, in_axes=(1, 0), out_axes=1)(q, q_pos)
    if shard_blocks is not None:
        out = shard_blocks(out)
    out = out.reshape(B, Sq, H, G, D)[:, :orig_sq]
    return out.astype(v.dtype)


def plain_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    kv_valid_len=None):
    """Reference O(S^2)-memory attention, used for short sequences/tests."""
    B, Sq, H, G, D = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bqhgk", q, k,
                   preferred_element_type=jnp.float32) / np.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    if kv_valid_len is not None:
        mask &= (k_pos < kv_valid_len)[None, :]
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bqhgk,bkhd->bqhgd", p, v)


# ---------------------------------------------------------------------------
# GQA attention module
# ---------------------------------------------------------------------------

def make_gqa_params(key, cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": normal(ks[0], (d, hkv, hq // hkv, hd), ("embed", "kv_heads", "q_per_kv", "head_dim")),
        "wk": normal(ks[1], (d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": normal(ks[2], (d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": normal(ks[3], (hkv, hq // hkv, hd, d), ("kv_heads", "q_per_kv", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((hkv, hq // hkv, hd), ("kv_heads", "q_per_kv", "head_dim"))
        p["bk"] = zeros((hkv, hd), ("kv_heads", "head_dim"))
        p["bv"] = zeros((hkv, hd), ("kv_heads", "head_dim"))
    return p


def gqa_project_qkv(params, x, positions, cfg):
    """x: (B,S,d) -> q (B,S,Hkv,G,D), k/v (B,S,Hkv,D), with RoPE applied."""
    q = jnp.einsum("bsd,dhgk->bshgk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    B, S, Hkv, G, D = q.shape
    sect = tuple(cfg.mrope_sections)
    q = layers.apply_rope(q.reshape(B, S, Hkv * G, D), positions,
                          cfg.rope_theta, sect).reshape(B, S, Hkv, G, D)
    k = layers.apply_rope(k, positions, cfg.rope_theta, sect)
    return q, k, v


def make_shard_blocks(dist, seq_len: int, q_block: int = 512):
    """Context-parallel constraint for blockwise attention: pick a q_block so
    the q-block dim tiles the model axis, and return (shard_fn, q_block)."""
    if dist is None:
        return None, q_block
    model_n = dist.mesh.shape.get("model", 1)
    if model_n > 1 and seq_len % model_n == 0 and seq_len // model_n >= 128:
        q_block = seq_len // model_n
    from ..distributed.sharding import batch_spec

    def fn(x, model_dim=1):
        extra = [None] * (x.ndim - 1)
        if model_dim is not None and x.shape[model_dim] % model_n == 0:
            extra[model_dim - 1] = "model"
        return dist.constrain(x, batch_spec(x.shape[0], dist.mesh,
                                            tuple(extra)))

    return fn, q_block


def gqa_attention(params, x, positions, cfg, *, causal=True, window=0,
                  use_blockwise=None, dist=None):
    q, k, v = gqa_project_qkv(params, x, positions, cfg)
    S = x.shape[1]
    if use_blockwise is None:
        use_blockwise = S > 1024
    if use_blockwise:
        shard_blocks, qb = make_shard_blocks(dist, S)
        o = blockwise_attention(q, k, v, causal=causal, window=window,
                                q_block=qb, shard_blocks=shard_blocks)
    else:
        o = plain_attention(q, k, v, causal=causal, window=window)
    return jnp.einsum("bshgk,hgkd->bsd", o, params["wo"])


def gqa_prefill_attention(params, x, positions, cfg, *, window=0, cap=None,
                          cache_dtype=jnp.bfloat16, dist=None):
    """Full-sequence attention that also returns the populated KV cache."""
    q, k, v = gqa_project_qkv(params, x, positions, cfg)
    S = x.shape[1]
    if S > 1024:
        shard_blocks, qb = make_shard_blocks(dist, S)
        o = blockwise_attention(q, k, v, causal=True, window=window,
                                q_block=qb, shard_blocks=shard_blocks)
    else:
        o = plain_attention(q, k, v, causal=True, window=window)
    out = jnp.einsum("bshgk,hgkd->bsd", o, params["wo"])
    cache = ContiguousLayout(window).from_seq(k, v, cap if cap else S,
                                              cache_dtype)
    return out, cache


# ---------------------------------------------------------------------------
# KV cache layouts (KVCacheLayout protocol)
#
# Caches are plain arrays so they stack/scan over layers cleanly; the
# absolute position `pos` is carried once at the model level. A *layout*
# object owns the mapping from (slot, position) to physical storage:
#
#   ContiguousLayout — {"k": (B, cap, Hkv, D), "v": ...}: each batch slot
#     owns a contiguous capacity-length row (ring buffer when windowed).
#   PagedLayout — {"k": (n_pages, page_size, Hkv, D), "v": ...}: one shared
#     pool of fixed-size pages; a per-slot *page table* (B, pages_per_slot)
#     of physical page ids provides the indirection, so KV capacity is
#     decoupled from the slot count and pages can be shared between slots
#     (prefix caching). The page table is always a *traced* integer leaf —
#     allocator churn changes values, never shapes, so nothing retraces.
#
# Layout objects are static (frozen dataclasses) and safe to close over in
# jitted code.
# ---------------------------------------------------------------------------

class KVCacheLayout(Protocol):
    """Protocol for decode-cache layouts (structural; both layouts below
    conform). ``init`` signatures differ per layout (per-slot rows vs a
    shared page pool) — see each class. ``page_table`` is accepted (and
    ignored) by the contiguous layout so call sites stay branch-free."""

    def read(self, cache, page_table=None, read_len: Optional[int] = None
             ) -> Tuple[jax.Array, jax.Array]:
        """Full (B, cap, Hkv, D) K/V views for batched decode. ``read_len``
        (static) trims the view to its first ``read_len`` rows — bitwise
        reproducibility across layouts requires attending over the SAME
        static width (XLA's reduction grouping depends on the axis length,
        so a wider zero-masked view is only ULP-equal, not bit-equal)."""
        ...

    def read_slot(self, cache, slot, page_table=None,
                  read_len: Optional[int] = None
                  ) -> Tuple[jax.Array, jax.Array]:
        """One slot's (cap, Hkv, D) K/V view (chunked prefill)."""
        ...

    def append(self, cache, k_new, v_new, pos, page_table=None,
               write_mask=None):
        """Insert one decode step (B,1,Hkv,D) at per-slot positions."""
        ...

    def append_chunk(self, cache, k_chunk, v_chunk, slot, start, valid_len,
                     page_table=None):
        """Insert a (C,Hkv,D) prompt chunk for one slot at absolute
        positions start..start+C-1 (rows >= valid_len dropped)."""
        ...

    def validity(self, pos_after, capacity: int):
        """(valid, abs_pos) masks of cache entries after ``pos_after``."""
        ...


@dataclasses.dataclass(frozen=True)
class ContiguousLayout:
    """Per-slot contiguous KV rows; ring buffer when ``window`` > 0.

    The adapter over the original cache dict — every pre-layout call site
    (decode, prefill capture, windowed decode) maps onto these methods."""
    window: int = 0

    def init(self, batch: int, length: int, n_kv: int, head_dim: int,
             dtype=jnp.bfloat16):
        """{"k": (B, length, Hkv, D), "v": ...}; ``length`` is the window
        size for windowed decode or the full context length otherwise."""
        return {"k": jnp.zeros((batch, length, n_kv, head_dim), dtype),
                "v": jnp.zeros((batch, length, n_kv, head_dim), dtype)}

    def from_seq(self, k, v, cap: int, dtype=jnp.bfloat16):
        """Turn full-sequence K/V (B,S,H,D) into a decode cache of capacity
        ``cap`` (ring layout when windowed, matching ``append``)."""
        B, S, H, D = k.shape
        if self.window > 0:
            w = min(cap, S)
            slots = (S - w + jnp.arange(w)) % cap
            kc = jnp.zeros((B, cap, H, D), dtype).at[:, slots].set(
                k[:, S - w:].astype(dtype))
            vc = jnp.zeros((B, cap, H, D), dtype).at[:, slots].set(
                v[:, S - w:].astype(dtype))
        else:
            assert cap >= S, f"cache capacity {cap} < prefill length {S}"
            kc = jnp.zeros((B, cap, H, D), dtype).at[:, :S].set(
                k.astype(dtype))
            vc = jnp.zeros((B, cap, H, D), dtype).at[:, :S].set(
                v.astype(dtype))
        return {"k": kc, "v": vc}

    def slot_index(self, pos, capacity: int):
        """Physical row of absolute position ``pos`` (ring when windowed)."""
        return pos % capacity if self.window > 0 else pos

    def read(self, cache, page_table=None, read_len=None):
        if read_len is not None:
            return cache["k"][:, :read_len], cache["v"][:, :read_len]
        return cache["k"], cache["v"]

    def read_slot(self, cache, slot, page_table=None, read_len=None):
        k = jax.lax.dynamic_index_in_dim(cache["k"], slot, 0, False)
        v = jax.lax.dynamic_index_in_dim(cache["v"], slot, 0, False)
        if read_len is not None:
            return k[:read_len], v[:read_len]
        return k, v

    def append(self, cache, k_new, v_new, pos, page_table=None,
               write_mask=None):
        """Insert one step (B,1,Hkv,D) at absolute position ``pos`` — a
        scalar (whole batch at one position) or a (B,) vector of per-slot
        ragged positions (out-of-capacity or ``~write_mask`` writes are
        dropped)."""
        cap = cache["k"].shape[1]
        idx = self.slot_index(pos, cap)
        if jnp.ndim(pos) == 1:
            if write_mask is not None:
                idx = jnp.where(write_mask, idx, cap)
            b = jnp.arange(k_new.shape[0])
            k = cache["k"].at[b, idx].set(
                k_new[:, 0].astype(cache["k"].dtype), mode="drop")
            v = cache["v"].at[b, idx].set(
                v_new[:, 0].astype(cache["v"].dtype), mode="drop")
            return {"k": k, "v": v}
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, idx, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, idx, 0, 0))
        return {"k": k, "v": v}

    def append_chunk(self, cache, k_chunk, v_chunk, slot, start, valid_len,
                     page_table=None):
        assert self.window == 0, "chunked prefill needs a non-ring layout"
        cap = cache["k"].shape[1]
        C = k_chunk.shape[0]
        i = jnp.arange(C)
        rows = jnp.where(i < valid_len, start + i, cap)       # drop invalid
        k = cache["k"].at[slot, rows].set(
            k_chunk.astype(cache["k"].dtype), mode="drop")
        v = cache["v"].at[slot, rows].set(
            v_chunk.astype(cache["v"].dtype), mode="drop")
        return {"k": k, "v": v}

    def validity(self, pos_after, capacity: int):
        return _cache_validity(pos_after, capacity, self.window)


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Block-granular KV cache: a pool of ``page_size``-token pages shared
    by all slots, addressed through a per-slot page table of physical page
    ids. Page 0 is conventionally a write sink ("trash page") for retired
    slots, so scheduler churn never needs a masked jit. Windowed (ring)
    caches are not supported — paging already bounds memory."""
    page_size: int

    def init(self, n_pages: int, n_kv: int, head_dim: int,
             dtype=jnp.bfloat16):
        """{"k": (n_pages, page_size, Hkv, D), "v": ...} — ONE pool; slots
        come from the page table, not from a batch axis."""
        return {"k": jnp.zeros((n_pages, self.page_size, n_kv, head_dim),
                               dtype),
                "v": jnp.zeros((n_pages, self.page_size, n_kv, head_dim),
                               dtype)}

    def slot_index(self, pos):
        """(logical page, in-page offset) of absolute position ``pos``."""
        return pos // self.page_size, pos % self.page_size

    def _gather(self, a, ids, lead, read_len):
        if read_len is not None:         # gather only the pages we need
            ids = ids[..., :-(-read_len // self.page_size)]
        g = jnp.take(a, ids.reshape(-1), axis=0)
        g = g.reshape(lead + (ids.shape[-1] * self.page_size,)
                      + a.shape[2:])
        if read_len is not None:
            g = g[..., :read_len, :, :] if lead else g[:read_len]
        return g

    def read(self, cache, page_table=None, read_len=None):
        """(B, pages_per_slot * page_size, Hkv, D) gathered views (trimmed
        to ``read_len`` rows when given — fewer pages gathered AND a view
        width that bit-matches a contiguous cache of that capacity)."""
        B = page_table.shape[0]
        return (self._gather(cache["k"], page_table, (B,), read_len),
                self._gather(cache["v"], page_table, (B,), read_len))

    def read_slot(self, cache, slot, page_table=None, read_len=None):
        row = jax.lax.dynamic_index_in_dim(page_table, slot, 0, False)
        return (self._gather(cache["k"], row, (), read_len),
                self._gather(cache["v"], row, (), read_len))

    def append(self, cache, k_new, v_new, pos, page_table=None,
               write_mask=None):
        """One decode step at per-slot positions ``pos`` (B,): the write
        lands at page_table[b, pos//ps][pos%ps]; slots beyond their table
        or outside ``write_mask`` are dropped."""
        n_pages = cache["k"].shape[0]
        n_logical = page_table.shape[1]
        page, off = self.slot_index(pos)
        phys = jnp.take_along_axis(
            page_table, jnp.minimum(page, n_logical - 1)[:, None],
            axis=1)[:, 0]
        phys = jnp.where(page < n_logical, phys, n_pages)
        if write_mask is not None:
            phys = jnp.where(write_mask, phys, n_pages)
        k = cache["k"].at[phys, off].set(
            k_new[:, 0].astype(cache["k"].dtype), mode="drop")
        v = cache["v"].at[phys, off].set(
            v_new[:, 0].astype(cache["v"].dtype), mode="drop")
        return {"k": k, "v": v}

    def append_chunk(self, cache, k_chunk, v_chunk, slot, start, valid_len,
                     page_table=None):
        n_pages = cache["k"].shape[0]
        row = jax.lax.dynamic_index_in_dim(page_table, slot, 0, False)
        n_logical = row.shape[0]
        C = k_chunk.shape[0]
        i = jnp.arange(C)
        page, off = self.slot_index(start + i)
        phys = row[jnp.minimum(page, n_logical - 1)]
        phys = jnp.where((i < valid_len) & (page < n_logical), phys, n_pages)
        k = cache["k"].at[phys, off].set(
            k_chunk.astype(cache["k"].dtype), mode="drop")
        v = cache["v"].at[phys, off].set(
            v_chunk.astype(cache["v"].dtype), mode="drop")
        return {"k": k, "v": v}

    def validity(self, pos_after, capacity: int):
        return _cache_validity(pos_after, capacity, 0)


# -- deprecated free-function shims (pre-KVCacheLayout API) -----------------

def init_kv_cache(batch: int, length: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16):
    """DEPRECATED shim: use ``ContiguousLayout(window).init(...)``."""
    warnings.warn(
        "init_kv_cache is deprecated; use ContiguousLayout(window).init(...)"
        " (KVCacheLayout API)", DeprecationWarning, stacklevel=2)
    return ContiguousLayout().init(batch, length, n_kv, head_dim, dtype)


def build_cache_from_seq(k, v, cap: int, window: int = 0,
                         dtype=jnp.bfloat16):
    """DEPRECATED shim: use ``ContiguousLayout(window).from_seq(...)``."""
    warnings.warn(
        "build_cache_from_seq is deprecated; use "
        "ContiguousLayout(window).from_seq(...) (KVCacheLayout API)",
        DeprecationWarning, stacklevel=2)
    return ContiguousLayout(window).from_seq(k, v, cap, dtype)


def _cache_slot(pos, capacity: int, window: int):
    """DEPRECATED shim: use ``ContiguousLayout(window).slot_index(...)``."""
    warnings.warn(
        "_cache_slot is deprecated; use "
        "ContiguousLayout(window).slot_index(pos, capacity)",
        DeprecationWarning, stacklevel=2)
    return ContiguousLayout(window).slot_index(pos, capacity)


def _cache_validity(pos_after, capacity: int, window: int):
    """Validity mask + absolute positions of cache slots after inserting the
    token at position pos_after-1 (ring buffer when windowed).

    ``pos_after`` may be a scalar (synchronized batch) or a (B,) vector of
    per-slot positions (continuous batching) — the vector form broadcasts to
    a (B, capacity) mask so each slot sees only its own ragged prefix."""
    slots = jnp.arange(capacity)
    if jnp.ndim(pos_after) == 1:
        pos_after = pos_after[:, None]                       # (B, 1)
    if window > 0:
        abs_pos = pos_after - 1 - ((pos_after - 1 - slots) % capacity)
        valid = (abs_pos >= 0) & (abs_pos > pos_after - 1 - window)
    else:
        abs_pos = jnp.broadcast_to(slots, jnp.broadcast_shapes(
            jnp.shape(pos_after), slots.shape))
        valid = slots < pos_after
    return valid, abs_pos


def kv_cache_insert(cache, k_new, v_new, pos, window: int = 0):
    """Insert one step (B,1,Hkv,D) at absolute position ``pos`` — a scalar
    (whole batch at one position) or a (B,) vector of per-slot ragged
    positions (out-of-capacity writes are dropped). Thin alias for
    ``ContiguousLayout(window).append``."""
    return ContiguousLayout(window).append(cache, k_new, v_new, pos)


def _valid_mask(valid, rank: int):
    """(cap,) or (B,cap) validity -> mask broadcastable against a score
    tensor of ``rank`` dims whose first axis is batch and last is the cache
    axis (shared by the GQA and MLA decode paths)."""
    lead = valid.shape[:1] if valid.ndim == 2 else (1,)
    return valid.reshape(lead + (1,) * (rank - 2) + valid.shape[-1:])


def _attend_cache(q, k_view, v_view, mask):
    """Softmax attention of q (B,Sq,Hkv,G,D) over cache views (B,T,Hkv,D)
    under a boolean ``mask`` broadcastable to the (B,Sq,Hkv,G,T) scores —
    the shared math of the decode and chunk-prefill paths. Op-for-op
    identical to ``plain_attention`` (same einsum specs, same divide-by-
    sqrt) so a float32 cache makes chunked prefill BIT-identical to the
    monolithic prefill: masked cache rows score exactly NEG_INF, exp to
    exactly 0, and contribute exact zeros to the softmax sum and the p@v
    contraction."""
    s = jnp.einsum("bqhgd,bkhd->bqhgk", q, k_view,
                   preferred_element_type=jnp.float32) / np.sqrt(q.shape[-1])
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_view.dtype)
    return jnp.einsum("bqhgk,bkhd->bqhgd", p, v_view)


def gqa_decode_attention(params, x, cache, pos, cfg, window: int = 0, *,
                         layout: Optional[KVCacheLayout] = None,
                         page_table=None, write_mask=None, read_len=None):
    """One-token decode: x (B,1,d) against the cache at absolute position
    ``pos`` — a scalar, or a (B,) vector of per-slot positions (continuous
    batching over ragged requests). Returns (out, new_cache).

    ``layout`` selects the cache storage (default ``ContiguousLayout(window)``
    for the legacy call sites); paged layouts also need ``page_table``
    (B, pages_per_slot) int32. ``write_mask`` (B,) bool suppresses the KV
    write for inactive slots (their query still runs, output is discarded by
    the caller) — required when decode interleaves with chunked prefill so a
    mid-prefill slot's page is not corrupted by the batched decode write."""
    if layout is None:
        layout = ContiguousLayout(window)
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    posb = pos[:, None] if pos.ndim == 1 else jnp.full((B, 1), pos, jnp.int32)
    if cfg.mrope_sections:
        posb = jnp.broadcast_to(posb[None], (3,) + posb.shape)
    q, k_new, v_new = gqa_project_qkv(params, x, posb, cfg)
    cache = layout.append(cache, k_new, v_new, pos, page_table=page_table,
                          write_mask=write_mask)
    k_view, v_view = layout.read(cache, page_table=page_table,
                                 read_len=read_len)
    valid, _ = layout.validity(pos + 1, k_view.shape[1])
    o = _attend_cache(q, k_view, v_view, _valid_mask(valid, 5))
    return jnp.einsum("bshgk,hgkd->bsd", o, params["wo"]), cache


def gqa_chunk_attention(params, x, cache, slot, start, valid_len, cfg, *,
                        layout: KVCacheLayout, page_table=None,
                        read_len=None):
    """Chunked-prefill attention for ONE slot: x (1,C,d) holds prompt tokens
    at absolute positions start..start+C-1 (rows >= ``valid_len`` are
    padding). Appends the chunk's K/V into the cache, then attends each
    chunk query over the slot's cache prefix (earlier chunks + this one,
    causally). Returns (out (1,C,d), new_cache).

    Fixed-shape by construction: C is static, ``slot``/``start``/
    ``valid_len`` are traced scalars, so one jit serves every chunk of every
    prompt."""
    C = x.shape[1]
    positions = start + jnp.arange(C, dtype=jnp.int32)[None, :]     # (1, C)
    posb = positions
    if cfg.mrope_sections:
        posb = jnp.broadcast_to(posb[None], (3,) + posb.shape)
    q, k_new, v_new = gqa_project_qkv(params, x, posb, cfg)
    cache = layout.append_chunk(cache, k_new[0], v_new[0], slot, start,
                                valid_len, page_table=page_table)
    k_slot, v_slot = layout.read_slot(cache, slot, page_table=page_table,
                                      read_len=read_len)
    k_view, v_view = k_slot[None], v_slot[None]                 # (1,T,Hkv,D)
    # query i (abs pos start+i) sees cache rows with abs pos <= start+i that
    # hold real tokens; rows of this chunk past valid_len were dropped, so
    # bounding by the query's own position suffices.
    q_abs = start + jnp.arange(C)                               # (C,)
    k_abs = jnp.arange(k_view.shape[1])                         # (T,)
    mask = (k_abs[None, :] <= q_abs[:, None])[None, :, None, None, :]
    o = _attend_cache(q, k_view, v_view, mask)
    return jnp.einsum("bshgk,hgkd->bsd", o, params["wo"]), cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention; MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------

def make_mla_params(key, cfg):
    d = cfg.d_model
    H = cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_a": normal(ks[0], (d, rq), ("embed", None)),
        "q_norm": ones((rq,), (None,)),
        "wq_b": normal(ks[1], (rq, H, dn + dr), (None, "heads", "head_dim")),
        "wkv_a": normal(ks[2], (d, rkv + dr), ("embed", None)),
        "kv_norm": ones((rkv,), (None,)),
        "wk_b": normal(ks[3], (rkv, H, dn), (None, "heads", "head_dim")),
        "wv_b": normal(ks[4], (rkv, H, dv), (None, "heads", "head_dim")),
        "wo": normal(ks[5], (H, dv, d), ("heads", "head_dim", "embed")),
    }


def mla_project_latent(params, x, cfg):
    """Compressed KV latent: returns (c_kv (B,S,rkv), k_rope (B,S,dr))."""
    rkv = cfg.kv_lora_rank
    kv_a = x @ params["wkv_a"]
    c_kv = layers.rms_norm(kv_a[..., :rkv], params["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., rkv:]
    return c_kv, k_rope


def mla_queries(params, x, positions, cfg):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_lat = layers.rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["wq_b"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(params, x, positions, cfg, *, causal=True, window=0,
                  dist=None):
    """Prefill/train path: decompress per-head K/V, blockwise attention."""
    B, S, _ = x.shape
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_nope, q_rope = mla_queries(params, x, positions, cfg)
    c_kv, k_rope = mla_project_latent(params, x, cfg)
    k_rope = layers.apply_rope(k_rope[..., None, :], positions,
                               cfg.rope_theta)[..., 0, :]
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"])
    H = cfg.n_heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]
    q = jnp.moveaxis(q, 2, 2)  # (B,S,H,1,dn+dr)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], axis=-1)
    # pad v to qk dim for the shared kernel, slice after
    dv = v.shape[-1]
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
    if S > 1024:
        shard_blocks, qb = make_shard_blocks(dist, S)
        o = blockwise_attention(q, k, v_pad, causal=causal, window=window,
                                q_block=qb, shard_blocks=shard_blocks)
    else:
        o = plain_attention(q, k, v_pad, causal=causal, window=window)
    o = o[..., 0, :dv]
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])


def init_mla_cache(batch, length, cfg, dtype=jnp.bfloat16):
    return {"c": jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, length, cfg.qk_rope_head_dim), dtype)}


def mla_prefill_attention(params, x, positions, cfg, *, window=0, cap=None,
                          cache_dtype=jnp.bfloat16, dist=None):
    """MLA prefill that also returns the populated latent cache."""
    out = mla_attention(params, x, positions, cfg, window=window, dist=dist)
    c_kv, k_rope = mla_project_latent(params, x, cfg)
    k_rope = layers.apply_rope(k_rope[..., None, :], positions,
                               cfg.rope_theta)[..., 0, :]
    S = x.shape[1]
    cap = cap if cap else S

    def ring(a):                                          # (B,S,F) -> (B,cap,F)
        B, _, F = a.shape
        if window > 0:
            w = min(cap, S)
            slots = (S - w + jnp.arange(w)) % cap
            return jnp.zeros((B, cap, F), cache_dtype).at[:, slots].set(
                a[:, S - w:].astype(cache_dtype))
        return jnp.zeros((B, cap, F), cache_dtype).at[:, :S].set(
            a.astype(cache_dtype))

    return out, {"c": ring(c_kv), "kr": ring(k_rope)}


def mla_decode_attention(params, x, cache, pos, cfg, window: int = 0):
    """Absorbed one-token decode against the compressed latent cache.

    q_nope is absorbed through wk_b into latent space so attention scores are
    computed directly against c_kv (rank-space) — the TPU-efficient MLA decode.
    ``pos`` may be a scalar or a (B,) per-slot vector (continuous batching).
    """
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    posb = pos[:, None] if per_slot else jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = mla_queries(params, x, posb, cfg)       # (B,1,H,dn/dr)
    c_new, kr_new = mla_project_latent(params, x, cfg)       # (B,1,rkv/dr)
    kr_new = layers.apply_rope(kr_new[..., None, :], posb,
                               cfg.rope_theta)[..., 0, :]
    cap = cache["c"].shape[1]
    idx = ContiguousLayout(window).slot_index(pos, cap)
    if per_slot:
        b = jnp.arange(B)
        c_kv = cache["c"].at[b, idx].set(
            c_new[:, 0].astype(cache["c"].dtype), mode="drop")
        k_rope = cache["kr"].at[b, idx].set(
            kr_new[:, 0].astype(cache["kr"].dtype), mode="drop")
    else:
        c_kv = jax.lax.dynamic_update_slice(
            cache["c"], c_new.astype(cache["c"].dtype), (0, idx, 0))
        k_rope = jax.lax.dynamic_update_slice(
            cache["kr"], kr_new.astype(cache["kr"].dtype), (0, idx, 0))
    cache = {"c": c_kv, "kr": k_rope}
    valid, _ = _cache_validity(pos + 1, cap, window)
    # absorb: q_eff (B,1,H,rkv)
    q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, params["wk_b"])
    scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    s = (jnp.einsum("bshr,btr->bsht", q_eff, c_kv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bshk,btk->bsht", q_rope, k_rope,
                      preferred_element_type=jnp.float32)) * scale
    s = jnp.where(_valid_mask(valid, s.ndim), s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(c_kv.dtype)
    o_lat = jnp.einsum("bsht,btr->bshr", p, c_kv)            # (B,1,H,rkv)
    o = jnp.einsum("bshr,rhk->bshk", o_lat, params["wv_b"])  # (B,1,H,dv)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"]), cache
