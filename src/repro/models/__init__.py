# Model substrate package. Submodules imported lazily to keep import costs
# low and avoid cycles; use `from repro.models import transformer` etc.
