"""Decoder-only transformer stack covering the dense / moe / ssm / hybrid /
vlm families, with jax.lax.scan over stacked layer params.

Three entry modes per model:
  * train/prefill forward over a full sequence (blockwise attention),
  * single-token decode against a cache (dict-of-arrays, stacked over layers).

Distribution is injected via ``DistContext`` — when present, the MoE layer
uses the S-ETP shard_map path (paper §3.3) and activations get sharding
constraints; when absent everything is single-device pure JAX (tests).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import drop as drop_mod
from ..core import gating
from ..core import moe as moe_mod
from ..core import setp as setp_mod
from ..obs import MetricsState, ObsCache
from . import attention as attn
from . import layers as L
from . import mamba2 as mm
from .layers import normal, ones


@dataclasses.dataclass(frozen=True)
class DistContext:
    """How to distribute the forward pass.

    MoE sparsity is configured by ONE object: ``policy`` (a
    ``core.policy.SparsityPolicy``; ``None`` means ``NoDrop``). The policy
    owns routing (which pairs to compute), the drop thresholds, and the
    execution hints (kernel choice, dispatch capacity factor, exact
    capacity for batch-composition-invariant serving). Params must have
    been prepared by the SAME policy (``policy.prepare``)."""
    mesh: Mesh
    moe_impl: str = "setp"        # "setp" (shard_map AlltoAll EP) | "gspmd"
    policy: Optional[Any] = None  # SparsityPolicy; None == NoDrop
    remat: bool = False           # activation checkpointing on blocks
    remat_policy: str = "none"    # none | dots — jax.checkpoint policy

    def constrain(self, x, spec: P):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def _maybe_constrain(x, dist: Optional[DistContext], spec):
    if dist is None:
        return x
    from ..distributed.sharding import batch_spec
    return dist.constrain(x, batch_spec(x.shape[0], dist.mesh, extra=spec))


def _residual_spec(dist: Optional[DistContext], seq_len: int,
                   family: str = "dense"):
    """Sequence parallelism: keep the (B, S, d) residual stream sharded over
    the model axis along S whenever it divides — norms/projections are
    per-token, attention context-parallelizes its q-blocks along the same
    boundaries, and the S-ETP MoE wants exactly this layout. Re-replicating
    between layers costs an all-gather of the full residual per layer.

    NOT for ssm/hybrid: the Mamba causal conv + chunk scan recur along S,
    so a seq-sharded residual forces halo exchanges/permutes every layer
    (measured: zamba2 train collectives 1.9 -> 4.7 s). Those families keep
    the batch-only layout."""
    if dist is None or family in ("ssm", "hybrid"):
        return (None, None)
    model_n = dist.mesh.shape.get("model", 1)
    if model_n > 1 and seq_len % model_n == 0 and seq_len // model_n >= 128:
        return ("model", None)
    return (None, None)


# ---------------------------------------------------------------------------
# Block params
# ---------------------------------------------------------------------------

def make_block_params(key, cfg):
    """One decoder block (pre-norm). Families:
    dense/vlm: attn + mlp; moe: attn + moe; ssm: mamba only."""
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm":
        return {"ln1": ones((cfg.d_model,), ("embed",)),
                "mamba": mm.make_mamba2_params(ks[0], cfg)}
    p: Dict[str, Any] = {"ln1": ones((cfg.d_model,), ("embed",)),
                         "ln2": ones((cfg.d_model,), ("embed",))}
    if cfg.attn_kind == "mla":
        p["attn"] = attn.make_mla_params(ks[0], cfg)
    else:
        p["attn"] = attn.make_gqa_params(ks[0], cfg)
    if cfg.is_moe:
        p["moe"] = moe_mod.make_moe_params(ks[1], cfg)
    else:
        p["mlp"] = L.make_mlp_params(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    return p


def make_hybrid_params(key, cfg):
    """Zamba2-style: stacked mamba blocks + ONE shared attention block
    (attn + its own mlp) applied every ``attn_every`` layers."""
    k1, k2 = jax.random.split(key)
    mamba_cfg = cfg
    stacked = L.stack_layer_params(
        k1, cfg.n_layers,
        lambda k: {"ln1": ones((cfg.d_model,), ("embed",)),
                   "mamba": mm.make_mamba2_params(k, cfg)})
    ks = jax.random.split(k2, 3)
    shared = {
        "ln1": ones((cfg.d_model,), ("embed",)),
        "attn": attn.make_gqa_params(ks[0], cfg),
        "ln2": ones((cfg.d_model,), ("embed",)),
        "mlp": L.make_mlp_params(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind),
    }
    return {"mamba_blocks": stacked, "shared_attn": shared}


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------

def _attn_forward(p, x, positions, cfg, *, window: int, dist,
                  capture_cap: int = 0, cache_dtype=jnp.bfloat16):
    """capture_cap > 0: also return the populated decode cache."""
    if cfg.attn_kind == "mla":
        if capture_cap:
            return attn.mla_prefill_attention(p, x, positions, cfg,
                                              window=window, cap=capture_cap,
                                              cache_dtype=cache_dtype,
                                              dist=dist)
        return attn.mla_attention(p, x, positions, cfg, window=window,
                                  dist=dist)
    if capture_cap:
        return attn.gqa_prefill_attention(p, x, positions, cfg,
                                          window=window, cap=capture_cap,
                                          cache_dtype=cache_dtype, dist=dist)
    return attn.gqa_attention(p, x, positions, cfg, window=window,
                              dist=dist)


def _policy_of(dist: Optional[DistContext]):
    if dist is not None and dist.policy is not None:
        return dist.policy
    from ..core.policy import NoDrop
    return NoDrop()


def _moe_forward(p, x, cfg, dist: Optional[DistContext], aux: bool = False,
                 collect: bool = False):
    """MoE layer forward under ``dist.policy`` (default ``NoDrop``).

    Returns ``(y, aux_loss, overflow)``: aux_loss is None unless ``aux``
    (training); overflow is the scalar count of token-expert pairs dropped
    by dispatch-capacity overflow (on the setp/shard_map path this is the
    psum'd global count across device-level and local-expert seating).

    ``collect``: the third return is instead the per-layer ``repro.obs``
    stats dict (kept-pair expert_load histogram over sub-expert ids plus
    kept_full/kept_major/dropped_pairs/overflow_pairs) — same routing,
    bit-identical ``y``."""
    B, S, d = x.shape
    aux_val = None
    if aux:
        aux_val = moe_mod.aux_loss_for(p, x.reshape(-1, d), cfg)
    policy = _policy_of(dist)
    if dist is not None and dist.moe_impl == "setp":
        if collect:
            y, stats = setp_mod.setp_moe_forward(p, x, cfg, dist.mesh,
                                                 policy=policy,
                                                 return_stats=True)
            return y, aux_val, stats
        y, overflow = setp_mod.setp_moe_forward(p, x, cfg, dist.mesh,
                                                policy=policy,
                                                return_overflow=True)
        return y, aux_val, overflow
    xt = x.reshape(-1, d)
    # per-request/per-slot threshold leaves come in shaped (B,): expand them
    # to per-token so routing broadcasts over the flattened (B*S, d) block
    policy = policy.per_token(B, S)
    pairs = policy.route(p, xt, cfg)
    # exact capacity: one expert receives at most one pair per token, so
    # capacity == T guarantees zero overflow drops at any load skew
    y, overflow = moe_mod.moe_forward_dispatch(
        p, xt, cfg, pairs=pairs, capacity_factor=policy.capacity_factor,
        capacity=policy.dispatch_capacity(xt.shape[0]),
        use_kernel=policy.use_kernel, return_overflow=True,
        mode_grouped=policy.kernel_mode_grouping,
        fused_pipeline=getattr(policy, "fused_pipeline", None))
    if collect:
        n_sub = p["w1"].shape[0]
        p_factor = pairs.idx.shape[1] // pairs.modes.shape[1]
        kf, km, dr = drop_mod.sub_pair_outcome_counts(pairs.keep, p_factor)
        stats = {"expert_load": gating.expert_histogram(pairs.idx, n_sub,
                                                        keep=pairs.keep),
                 "kept_full": kf, "kept_major": km, "dropped_pairs": dr,
                 "overflow_pairs": overflow}
        return y.reshape(B, S, d), aux_val, stats
    return y.reshape(B, S, d), aux_val, overflow


def block_forward(bp, x, positions, cfg, *, window: int = 0,
                  dist: Optional[DistContext] = None, capture_cap: int = 0,
                  cache_dtype=jnp.bfloat16, with_aux: bool = False,
                  collect_stats: bool = False):
    """Full-sequence block forward (train / prefill). With capture_cap the
    return is (x, cache_layer, moe_overflow) for the prefill->decode
    handoff (with ``collect_stats`` the third slot is the per-layer obs
    stats dict instead); with_aux returns (x, load-balance aux loss) for
    MoE training."""
    no_overflow = jnp.zeros((), jnp.int32)
    if cfg.family == "ssm" or "mamba" in bp:
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        if capture_cap:
            y, st = mm.mamba2_forward(bp["mamba"], h, cfg, return_state=True)
            return x + y, st, no_overflow
        x = x + mm.mamba2_forward(bp["mamba"], h, cfg)
        return (x, jnp.zeros(())) if with_aux else x
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    cache_layer = None
    if capture_cap:
        y, cache_layer = _attn_forward(bp["attn"], h, positions, cfg,
                                       window=window, dist=dist,
                                       capture_cap=capture_cap,
                                       cache_dtype=cache_dtype)
        x = x + y
    else:
        x = x + _attn_forward(bp["attn"], h, positions, cfg, window=window,
                              dist=dist)
    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    overflow = no_overflow
    if "moe" in bp:
        if with_aux:
            y, aux, _ = _moe_forward(bp["moe"], h, cfg, dist, aux=True)
            x = x + y
            return x, aux
        y, _, overflow = _moe_forward(bp["moe"], h, cfg, dist,
                                      collect=collect_stats)
        x = x + y
    else:
        x = x + L.apply_mlp(bp["mlp"], h, cfg.mlp_kind)
    if with_aux:
        return x, jnp.zeros(())
    return (x, cache_layer, overflow) if capture_cap else x


def block_decode(bp, x, cache_layer, pos, cfg, *, window: int = 0,
                 dist: Optional[DistContext] = None, layout=None,
                 page_table=None, write_mask=None, read_len=None,
                 collect_stats: bool = False):
    """One-token decode. cache_layer is this layer's cache dict slice.
    Returns (x, cache_layer, moe_overflow) — or the per-layer obs stats
    dict in the third slot under ``collect_stats``. ``layout``/
    ``page_table``/``write_mask`` select the KV storage (see
    gqa_decode_attention)."""
    no_overflow = jnp.zeros((), jnp.int32)
    if cfg.family == "ssm" or "mamba" in bp:
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        st = mm.MambaState(cache_layer["conv"], cache_layer["ssm"])
        y, st = mm.mamba2_decode(bp["mamba"], h, st, cfg)
        return x + y, {"conv": st.conv, "ssm": st.ssm}, no_overflow
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        y, cache_layer = attn.mla_decode_attention(
            bp["attn"], h, cache_layer, pos, cfg, window)
    else:
        y, cache_layer = attn.gqa_decode_attention(
            bp["attn"], h, cache_layer, pos, cfg, window,
            layout=layout, page_table=page_table, write_mask=write_mask,
            read_len=read_len)
    x = x + y
    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    overflow = no_overflow
    if "moe" in bp:
        y, _, overflow = _moe_forward(bp["moe"], h, cfg, dist,
                                      collect=collect_stats)
        x = x + y
    else:
        x = x + L.apply_mlp(bp["mlp"], h, cfg.mlp_kind)
    return x, cache_layer, overflow


# ---------------------------------------------------------------------------
# Model params
# ---------------------------------------------------------------------------

def make_model_params(key, cfg):
    k_emb, k_blocks, k_fin = jax.random.split(key, 3)
    p: Dict[str, Any] = {
        "embed": L.make_embed_params(k_emb, cfg.vocab_size, cfg.d_model,
                                     cfg.tie_embeddings),
        "final_norm": ones((cfg.d_model,), ("embed",)),
    }
    if cfg.family == "hybrid":
        p.update(make_hybrid_params(k_blocks, cfg))
    else:
        p["blocks"] = L.stack_layer_params(
            k_blocks, cfg.n_layers, lambda k: make_block_params(k, cfg))
    if cfg.frontend:
        # stub frontends provide embeddings directly; a linear projector
        # adapts them to d_model (the one real parameter of the stub).
        p["frontend_proj"] = normal(k_fin, (cfg.d_model, cfg.d_model),
                                    ("embed", None))
    return p


# ---------------------------------------------------------------------------
# Stack forward (scan over layers)
# ---------------------------------------------------------------------------

def _positions_for(cfg, B, S, offset=0):
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.mrope_sections:
        # stub M-RoPE positions: text-style (t == h == w); real VLM inputs
        # may pass explicit (3,B,S) grids via batch["positions"]
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def stack_forward(params, x, positions, cfg, *, window: int = 0,
                  dist: Optional[DistContext] = None, capture_cap: int = 0,
                  cache_dtype=jnp.bfloat16, with_aux: bool = False,
                  metrics: bool = True):
    """x: (B,S,d) -> (B,S,d) through all blocks. With capture_cap also
    returns the layer-stacked decode cache (prefill); with_aux returns
    (x, summed MoE load-balance aux loss).

    ``metrics`` (MoE + capture only): the captured cache carries a
    ``"metrics"`` MetricsState (per-layer expert-load histograms + sub-pair
    outcome counters) instead of the legacy ``"moe_overflow"`` scalar;
    decode steps accumulate into it on device."""
    if cfg.family == "hybrid":
        out = _hybrid_forward(params, x, positions, cfg, window=window,
                              dist=dist, capture_cap=capture_cap,
                              cache_dtype=cache_dtype)
        return (out, jnp.zeros(())) if with_aux else out

    collect = bool(metrics and capture_cap and cfg.is_moe)
    fwd = functools.partial(block_forward, cfg=cfg, window=window, dist=dist,
                            capture_cap=capture_cap, cache_dtype=cache_dtype,
                            with_aux=with_aux, collect_stats=collect)
    if dist is not None and dist.remat and not capture_cap:
        policy = None
        if dist.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        fwd = jax.checkpoint(fwd, policy=policy)

    res_spec = _residual_spec(dist, x.shape[1], cfg.family)

    def body(h, bp):
        h = _maybe_constrain(h, dist, res_spec)
        if capture_cap:
            h2, cl, of = fwd(bp, h, positions)
            return h2, (cl, of)
        out = fwd(bp, h, positions)
        if with_aux:
            return out
        return out, None

    x, caches = jax.lax.scan(body, x, params["blocks"])
    if capture_cap:
        layers, ofs = caches
        cache = ObsCache({"layers": layers})
        if collect:
            # scan stacked the per-layer stats dicts to (n_layers, ...)
            cache["metrics"] = MetricsState.from_stacked(ofs)
        else:
            cache["moe_overflow"] = jnp.sum(ofs)
        return x, cache
    if with_aux:
        return x, jnp.sum(caches)
    return x


def _hybrid_forward(params, x, positions, cfg, *, window: int = 0,
                    dist: Optional[DistContext] = None, capture_cap: int = 0,
                    cache_dtype=jnp.bfloat16):
    """Zamba2: shared attention block before every ``attn_every``-th mamba
    layer; mamba segments run under scan, attention occurrences are a python
    loop over the (small) number of groups so FLOPs are exact."""
    n = cfg.n_layers
    every = cfg.attn_every
    n_occ = (n + every - 1) // every
    shared = params["shared_attn"]
    attn_caches = []
    mamba_caches = []

    mamba_fwd = functools.partial(block_forward, cfg=cfg, dist=dist,
                                  capture_cap=capture_cap,
                                  cache_dtype=cache_dtype)
    if dist is not None and dist.remat and not capture_cap:
        mamba_fwd = jax.checkpoint(mamba_fwd)

    def mamba_body(h, bp):
        if capture_cap:
            h2, st, _ = mamba_fwd(bp, h, positions)
            return h2, st
        return mamba_fwd(bp, h, positions), None

    for occ in range(n_occ):
        lo, hi = occ * every, min((occ + 1) * every, n)
        h = L.rms_norm(x, shared["ln1"], cfg.norm_eps)
        if capture_cap:
            y, ac = attn.gqa_prefill_attention(shared["attn"], h, positions,
                                               cfg, window=window,
                                               cap=capture_cap,
                                               cache_dtype=cache_dtype)
            attn_caches.append(ac)
            x = x + y
        else:
            x = x + attn.gqa_attention(shared["attn"], h, positions, cfg,
                                       window=window)
        h = L.rms_norm(x, shared["ln2"], cfg.norm_eps)
        x = x + L.apply_mlp(shared["mlp"], h, cfg.mlp_kind)
        seg = jax.tree.map(lambda a: a[lo:hi], params["mamba_blocks"])
        x, segc = jax.lax.scan(mamba_body, x, seg)
        if capture_cap:
            mamba_caches.append(segc)
    if capture_cap:
        cache = ObsCache({
            "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                                  *mamba_caches),
            "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *attn_caches),
            "moe_overflow": jnp.zeros((), jnp.int32),
        })
        return x, cache
    return x


def stack_decode(params, x, cache, pos, cfg, *, window: int = 0,
                 dist: Optional[DistContext] = None, layout=None,
                 page_table=None, write_mask=None, read_len=None):
    """One-token decode through all blocks. cache: layer-stacked dict."""
    if cfg.family == "hybrid":
        return _hybrid_decode(params, x, cache, pos, cfg, window=window,
                              dist=dist)

    # static gate: whether stats flow is decided by the cache's pytree
    # STRUCTURE (the "metrics" key), never by leaf values — so metric
    # value churn can't retrace
    collect = "metrics" in cache

    def body(h, xs):
        bp, cl = xs
        h, cl, of = block_decode(bp, h, cl, pos, cfg, window=window,
                                 dist=dist, layout=layout,
                                 page_table=page_table,
                                 write_mask=write_mask, read_len=read_len,
                                 collect_stats=collect)
        return h, (cl, of)

    x, (new_layers, ofs) = jax.lax.scan(
        body, x, (params["blocks"], cache["layers"]))
    new = ObsCache({"layers": new_layers})
    if collect:                   # device-side accumulation, no host sync
        new["metrics"] = cache["metrics"].accumulate(ofs)
    elif "moe_overflow" in cache:  # legacy running total across steps
        new["moe_overflow"] = cache["moe_overflow"] + jnp.sum(ofs)
    return x, new


def _hybrid_decode(params, x, cache, pos, cfg, *, window: int = 0,
                   dist: Optional[DistContext] = None):
    n, every = cfg.n_layers, cfg.attn_every
    n_occ = (n + every - 1) // every
    shared = params["shared_attn"]
    new_attn = {"k": [], "v": []}
    mamba_cache = cache["mamba"]
    new_mamba = []

    def mamba_body(h, xs):
        bp, cl = xs
        h, cl, _ = block_decode(bp, h, cl, pos, cfg, dist=dist)
        return h, cl

    for occ in range(n_occ):
        lo, hi = occ * every, min((occ + 1) * every, n)
        h = L.rms_norm(x, shared["ln1"], cfg.norm_eps)
        acache = {"k": cache["attn"]["k"][occ], "v": cache["attn"]["v"][occ]}
        y, acache = attn.gqa_decode_attention(shared["attn"], h, acache, pos,
                                              cfg, window)
        x = x + y
        new_attn["k"].append(acache["k"])
        new_attn["v"].append(acache["v"])
        h = L.rms_norm(x, shared["ln2"], cfg.norm_eps)
        x = x + L.apply_mlp(shared["mlp"], h, cfg.mlp_kind)
        seg_p = jax.tree.map(lambda a: a[lo:hi], params["mamba_blocks"])
        seg_c = jax.tree.map(lambda a: a[lo:hi], mamba_cache)
        x, seg_c = jax.lax.scan(mamba_body, x, (seg_p, seg_c))
        new_mamba.append(seg_c)
    new_cache = ObsCache({
        "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_mamba),
        "attn": {"k": jnp.stack(new_attn["k"]), "v": jnp.stack(new_attn["v"])},
    })
    if "moe_overflow" in cache:
        new_cache["moe_overflow"] = cache["moe_overflow"]
    return x, new_cache


# ---------------------------------------------------------------------------
# Top-level forwards
# ---------------------------------------------------------------------------

def embed_inputs(params, batch, cfg, offset=0):
    """Token embeddings (+ stub frontend embeddings prepended for vlm/audio
    decoder-only archs). Returns (x, positions, n_prefix)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    n_prefix = 0
    if cfg.frontend == "vision" and "frontend" in batch:
        fe = batch["frontend"] @ params["frontend_proj"]
        x = jnp.concatenate([fe.astype(x.dtype), x], axis=1)
        n_prefix = fe.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = _positions_for(cfg, B, x.shape[1], offset)
    return x, positions, n_prefix


def forward(params, batch, cfg, *, window: int = 0,
            dist: Optional[DistContext] = None, with_aux: bool = False):
    """Full-sequence forward -> logits (B, S, vocab) over the token part.
    with_aux additionally returns the summed MoE load-balance loss."""
    x, positions, n_prefix = embed_inputs(params, batch, cfg)
    x = _maybe_constrain(x, dist, _residual_spec(dist, x.shape[1],
                                                 cfg.family))
    aux = jnp.zeros(())
    if with_aux:
        x, aux = stack_forward(params, x, positions, cfg, window=window,
                               dist=dist, with_aux=True)
    else:
        x = stack_forward(params, x, positions, cfg, window=window,
                          dist=dist)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = L.unembed(params["embed"], x)
    if dist is not None:
        logits = _maybe_constrain(logits, dist, (None, "model"))
    return (logits, aux) if with_aux else logits


def prefill(params, batch, cfg, *, cache_len: int = 0, window: int = 0,
            dist: Optional[DistContext] = None, cache_dtype=jnp.bfloat16,
            metrics: bool = True):
    """Prefill: full forward AND populated decode cache.

    Returns (logits (B,S,vocab), cache) with cache["pos"] set past the
    prompt (including any frontend prefix). ``metrics``: MoE caches carry
    a ``"metrics"`` MetricsState (see ``repro.obs``) instead of the legacy
    ``"moe_overflow"`` scalar."""
    x, positions, n_prefix = embed_inputs(params, batch, cfg)
    S_total = x.shape[1]
    cap = max(cache_len, S_total) if not window else \
        min(cache_len if cache_len else S_total, window)
    x = _maybe_constrain(x, dist, _residual_spec(dist, S_total, cfg.family))
    x, cache = stack_forward(params, x, positions, cfg, window=window,
                             dist=dist, capture_cap=cap,
                             cache_dtype=cache_dtype, metrics=metrics)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = L.unembed(params["embed"], x)
    cache["pos"] = jnp.asarray(S_total, jnp.int32)
    return logits, cache


def decode_step(params, token, cache, cfg, *, window: int = 0,
                dist: Optional[DistContext] = None, layout=None,
                page_table=None, write_mask=None, read_len=None):
    """token: (B,1) -> (logits (B,1,vocab), new cache). cache carries 'pos' —
    a scalar shared by the batch (synchronized decode) or a (B,) vector of
    per-slot positions (continuous batching over ragged requests).

    ``layout``/``page_table`` select the KV storage: with a ``PagedLayout``
    the cache holds one page pool per layer and ``page_table`` (B, P) int32
    maps each slot's logical pages to physical ones. ``write_mask`` (B,)
    suppresses KV writes for inactive slots (their pos still advances; the
    engine owns per-slot positions)."""
    pos = cache["pos"]
    x = L.embed(params["embed"], token)
    x, new_cache = stack_decode(params, x, cache, pos, cfg, window=window,
                                dist=dist, layout=layout,
                                page_table=page_table, write_mask=write_mask,
                                read_len=read_len)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# Chunked prefill (one slot, fixed-shape chunks)
# ---------------------------------------------------------------------------

def chunk_block(bp, x, cache_layer, slot, start, valid_len, cfg, *,
                layout, page_table=None, read_len=None,
                dist: Optional[DistContext] = None,
                collect_stats: bool = False):
    """One block over a (1,C,d) prompt chunk of a single slot, appending its
    K/V into the decode cache. Returns (x, cache_layer, moe_overflow) —
    obs stats dict in the third slot under ``collect_stats``."""
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    y, cache_layer = attn.gqa_chunk_attention(
        bp["attn"], h, cache_layer, slot, start, valid_len, cfg,
        layout=layout, page_table=page_table, read_len=read_len)
    x = x + y
    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    overflow = jnp.zeros((), jnp.int32)
    if "moe" in bp:
        y, _, overflow = _moe_forward(bp["moe"], h, cfg, dist,
                                      collect=collect_stats)
        x = x + y
    else:
        x = x + L.apply_mlp(bp["mlp"], h, cfg.mlp_kind)
    return x, cache_layer, overflow


def chunk_step(params, tokens, slot, start, valid_len, cache, cfg, *,
               layout, page_table=None, read_len=None,
               dist: Optional[DistContext] = None):
    """Advance ONE slot's prompt by a fixed-size chunk.

    tokens: (1, C) prompt tokens at absolute positions start..start+C-1
    (rows >= ``valid_len`` are padding: their K/V writes are dropped and
    their logits are garbage the caller must ignore). Returns
    (logits (1, C, vocab), new cache) with cache['pos'][slot] advanced to
    start + valid_len.

    C is static; slot/start/valid_len are traced scalars — one jit serves
    every chunk of every prompt. Only gqa-attention, non-windowed families
    support chunked prefill (ssm/hybrid state and MLA latent caches have no
    per-slot chunk insert)."""
    assert cfg.family not in ("ssm", "hybrid") and cfg.attn_kind != "mla", \
        "chunked prefill requires gqa attention"
    slot = jnp.asarray(slot, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    valid_len = jnp.asarray(valid_len, jnp.int32)
    x = L.embed(params["embed"], tokens)

    collect = "metrics" in cache  # static structural gate, as stack_decode

    def body(h, xs):
        bp, cl = xs
        h, cl, of = chunk_block(bp, h, cl, slot, start, valid_len, cfg,
                                layout=layout, page_table=page_table,
                                read_len=read_len, dist=dist,
                                collect_stats=collect)
        return h, (cl, of)

    x, (new_layers, ofs) = jax.lax.scan(
        body, x, (params["blocks"], cache["layers"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    new_cache = ObsCache({"layers": new_layers,
                          "pos": cache["pos"].at[slot].set(start + valid_len)})
    if collect:
        new_cache["metrics"] = cache["metrics"].accumulate(ofs)
    elif "moe_overflow" in cache:
        new_cache["moe_overflow"] = cache["moe_overflow"] + jnp.sum(ofs)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, context_len: int, *, window: int = 0,
               dtype=jnp.bfloat16, per_slot_pos: bool = False,
               metrics_spec=None):
    """Layer-stacked decode cache. ``context_len`` is the KV capacity
    (== window when windowed). ``per_slot_pos`` makes cache['pos'] a (B,)
    vector so each batch slot decodes at its own ragged position.
    ``metrics_spec``: an (n_layers, n_sub_experts) pair (see
    ``repro.obs.metrics_spec``) — the cache then carries a zeroed
    ``"metrics"`` MetricsState instead of the legacy ``"moe_overflow"``
    scalar, and decode steps accumulate obs stats into it."""
    cap = min(window, context_len) if window else context_len
    hd = cfg.resolved_head_dim

    def one_attn():
        return attn.ContiguousLayout(window).init(batch, cap, cfg.n_kv_heads,
                                                  hd, dtype)

    def one_mamba():
        st = mm.init_mamba_state(batch, cfg, jnp.float32)
        return {"conv": st.conv, "ssm": st.ssm}

    if cfg.family == "hybrid":
        n_occ = (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every
        cache = {
            "mamba": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[one_mamba() for _ in range(cfg.n_layers)]),
            "attn": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[one_attn() for _ in range(n_occ)]),
        }
    elif cfg.family == "ssm":
        cache = {"layers": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[one_mamba() for _ in range(cfg.n_layers)])}
    elif cfg.attn_kind == "mla":
        cache = {"layers": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[attn.init_mla_cache(batch, cap, cfg, dtype)
              for _ in range(cfg.n_layers)])}
    else:
        cache = {"layers": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[one_attn() for _ in range(cfg.n_layers)])}
    cache = ObsCache(cache)
    cache["pos"] = jnp.zeros((batch,) if per_slot_pos else (), jnp.int32)
    if metrics_spec is not None:
        cache["metrics"] = MetricsState.zeros(*metrics_spec)
    else:
        # legacy: running count of token-expert pairs dropped by
        # dispatch-capacity overflow (accumulated by decode steps)
        cache["moe_overflow"] = jnp.zeros((), jnp.int32)
    return cache


def init_paged_cache(cfg, n_pages: int, page_size: int, n_slots: int, *,
                     dtype=jnp.bfloat16, metrics_spec=None):
    """Layer-stacked PAGED decode cache: one (n_pages, page_size, Hkv, D)
    pool per layer, shared by all slots through a per-slot page table the
    engine owns (the same logical->physical mapping applies to every
    layer). Physical page 0 is reserved as the write sink for retired
    slots. cache['pos'] is always per-slot (n_slots,)."""
    assert cfg.family not in ("ssm", "hybrid") and cfg.attn_kind != "mla", \
        "paged KV requires gqa attention"
    layout = attn.PagedLayout(page_size)
    hd = cfg.resolved_head_dim
    cache = ObsCache({"layers": jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[layout.init(n_pages, cfg.n_kv_heads, hd, dtype)
          for _ in range(cfg.n_layers)])})
    cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
    if metrics_spec is not None:
        cache["metrics"] = MetricsState.zeros(*metrics_spec)
    else:
        cache["moe_overflow"] = jnp.zeros((), jnp.int32)
    return cache
