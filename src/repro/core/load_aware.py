"""Load-aware thresholding in Expert Parallelism (paper §4.3).

The MoE step is blocked by the most-loaded EP device, so a uniform drop
threshold wastes accuracy on lightly-loaded devices. The paper's step-down
rule: compute each device's load ratio r_d = actual / ideal; devices with
r_d >= 1 use the maximum threshold T_max, devices with r_d < 1 reduce the
threshold proportionally to the deviation from 1.

Everything here is pure JAX so it runs inside the shard_map EP body with a
single psum of the (E,) routing histogram as the only communication.
"""
from __future__ import annotations

import jax.numpy as jnp


def device_loads(hist, experts_per_device: int):
    """hist: (E,) global token counts per expert -> (D,) per-device loads
    in f32 (downstream threshold math is float; summing in f32 explicitly
    avoids both int-overflow on big histograms and x64-dependent int64
    promotion of the reduction)."""
    E = hist.shape[0]
    D = E // experts_per_device
    return hist.reshape(D, experts_per_device).astype(jnp.float32).sum(axis=1)


def step_down_thresholds(loads, t_max: float):
    """Paper §4.3 rule. loads: (D,) -> per-device f32 thresholds (D,)."""
    t_max = jnp.asarray(t_max, jnp.float32)
    loads = loads.astype(jnp.float32)
    ideal = jnp.mean(loads)
    ratio = loads / jnp.maximum(ideal, 1e-9)
    return jnp.where(ratio >= 1.0, t_max, t_max * ratio)


def pair_thresholds(idx, loads, experts_per_device: int, t_max: float,
                    t_gap: float = 0.01):
    """Per-(token,expert)-pair 2T thresholds from the target device's load.

    idx: (T, K) *original* expert ids. Returns (t_major, t_minor) each (T, K).
    The ±t_gap split mirrors T²_major = T¹ - 0.01 / T²_minor = T¹ + 0.01.
    """
    t_dev = step_down_thresholds(loads, t_max)                 # (D,)
    dev_of_pair = idx // experts_per_device                    # (T, K)
    t1 = t_dev[dev_of_pair]
    return jnp.maximum(t1 - t_gap, 0.0), t1 + t_gap


def makespan(loads):
    """EP step time proxy == max device load (paper: 'blocked by the device
    with the heaviest computational load')."""
    return jnp.max(loads)


def post_drop_loads(hist_kept, experts_per_device: int):
    return device_loads(hist_kept, experts_per_device)
