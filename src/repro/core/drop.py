"""Token-expert computation dropping (paper §4.1-§4.2).

1T-Drop: drop pairs whose normalized gating score < T¹.
2T-Drop: with each original expert partitioned+reconstructed into a MAJOR
and MINOR sub-expert (partial transformation, P=2):

    score <= T²_major                -> drop both halves      (mode 0)
    T²_major < score <= T²_minor     -> compute major only    (mode 1)
    score >  T²_minor                -> compute both halves   (mode 2)

Both comparisons are strict ``>`` keeps, matching 1T-Drop's boundary
(``one_t_keep``: retain scores *exceeding* T¹), so setting
T²_major == T²_minor == T¹ degenerates 2T-Drop to 1T-Drop exactly —
including at score == T¹ — and ``threshold_to_drop_rate`` (which counts
``score <= t`` as dropped) is consistent with both.

Defaults (paper §4.2): T²_major = T¹ - 0.01, T²_minor = T¹ + 0.01.
All decisions are pure functions of the routing — fixed shapes, jit-safe.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

MODE_DROP, MODE_MAJOR, MODE_FULL = 0, 1, 2


def one_t_keep(norm_score, t_drop):
    """(T,K) bool keep mask. Paper retains scores *exceeding* the threshold."""
    t_drop = jnp.asarray(t_drop)
    t = t_drop[..., None] if jnp.ndim(t_drop) >= 1 else t_drop
    return norm_score > t


def two_t_modes(norm_score, t_major, t_minor):
    """(T,K) int32 modes per original token-expert pair. Thresholds may be
    scalar, per-token (T,), or per-pair (T,K) — e.g. load-aware. Both
    boundaries are strict ``>`` keeps (see module docstring) so
    t_major == t_minor reduces to ``one_t_keep`` bit for bit."""
    t_major = jnp.asarray(t_major)
    t_minor = jnp.asarray(t_minor)
    if jnp.ndim(t_major) == 1:
        t_major = t_major[:, None]
        t_minor = t_minor[:, None]
    full = norm_score > t_minor
    major = norm_score > t_major
    return jnp.where(full, MODE_FULL, jnp.where(major, MODE_MAJOR, MODE_DROP))


class SubExpertPairs(NamedTuple):
    """Token/sub-expert pair list after partial transformation (Eq. 12)."""
    idx: jax.Array        # (T, K*P) sub-expert ids
    combine: jax.Array    # (T, K*P) combine weights (repeated, Eq. 13)
    keep: jax.Array       # (T, K*P) bool — pair survives the drop
    modes: jax.Array      # (T, K) original-expert modes (diagnostics)


def expand_pairs_2t(idx, combine, norm_score, p: int,
                    t_major, t_minor) -> SubExpertPairs:
    """Partial transformation of the routing (Eq. 12) + 2T keep mask.

    Sub-expert p of original expert e has id e*P + p. With reconstruction,
    sub-expert 0 holds the MAJOR neurons, 1..P-1 the minor ones (P=2 in the
    paper; we keep P general — minor halves share the minor threshold).
    """
    T, K = idx.shape
    modes = two_t_modes(norm_score, t_major, t_minor)          # (T,K)
    sub = jnp.arange(p, dtype=idx.dtype)                       # (P,)
    new_idx = (idx[:, :, None] * p + sub[None, None, :])       # (T,K,P)
    new_combine = jnp.repeat(combine[:, :, None], p, axis=2)
    keep_major = modes >= MODE_MAJOR                           # (T,K)
    keep_minor = modes >= MODE_FULL
    keep = jnp.where(sub[None, None, :] == 0,
                     keep_major[:, :, None], keep_minor[:, :, None])
    return SubExpertPairs(
        idx=new_idx.reshape(T, K * p),
        combine=new_combine.reshape(T, K * p),
        keep=keep.reshape(T, K * p),
        modes=modes,
    )


def expand_pairs_1t(idx, combine, norm_score, p: int, t_drop) -> SubExpertPairs:
    """Partial transformation + 1T drop (all-or-nothing per original expert)."""
    T, K = idx.shape
    keep1 = one_t_keep(norm_score, t_drop)                     # (T,K)
    sub = jnp.arange(p, dtype=idx.dtype)
    new_idx = (idx[:, :, None] * p + sub[None, None, :]).reshape(T, K * p)
    new_combine = jnp.repeat(combine[:, :, None], p, axis=2).reshape(T, K * p)
    keep = jnp.repeat(keep1[:, :, None], p, axis=2).reshape(T, K * p)
    modes = jnp.where(keep1, MODE_FULL, MODE_DROP)
    return SubExpertPairs(new_idx, new_combine, keep, modes)


def drop_rate(pairs: SubExpertPairs) -> jax.Array:
    """Fraction of token-(sub-)expert computations dropped (paper's metric)."""
    return 1.0 - jnp.mean(pairs.keep.astype(jnp.float32))


def sub_pair_outcome_counts(keep, p: int):
    """Classify sub-pair outcomes from a keep mask alone (no modes needed,
    so it works on both the dispatch path and inside the S-ETP body).

    keep: (T, K*P) bool over expanded sub-expert pairs, P-major layout
    (``expand_pairs_*``: sub 0 = MAJOR half). A pair ran FULL when any of
    its minor halves survived; a kept pair with only the major half is
    MAJOR-only. With P == 1 there is no minor half, so every kept pair
    counts as FULL.

    Returns (kept_full, kept_major, dropped) int32 scalars counted in
    sub-pair units (kept_full + kept_major + dropped == T*K*P)."""
    T, Kp = keep.shape
    kp = keep.reshape(T, Kp // p, p)
    full = kp[..., 1:].any(-1) if p > 1 else kp[..., 0]
    per_pair = kp.sum(-1, dtype=jnp.int32)
    kept_full = jnp.sum(jnp.where(full, per_pair, 0), dtype=jnp.int32)
    kept_major = jnp.sum(jnp.where(full, 0, per_pair), dtype=jnp.int32)
    dropped = jnp.int32(T * Kp) - kept_full - kept_major
    return kept_full, kept_major, dropped


def flops_saved_fraction(modes) -> jax.Array:
    """Fraction of expert FLOPs skipped: mode 0 saves 1, mode 1 saves 1/2."""
    saved = jnp.where(modes == MODE_DROP, 1.0,
                      jnp.where(modes == MODE_MAJOR, 0.5, 0.0))
    return jnp.mean(saved)


def threshold_to_drop_rate(norm_scores, thresholds):
    """Empirical threshold->drop-rate map (paper Fig. 12) from calibration
    normalized scores (N,K). thresholds: (M,). Returns (M,) f32 drop rates.

    All math pinned to f32: under ``jax_enable_x64`` the bool-mean and the
    Python-float threshold list would otherwise silently promote to f64
    (caught by ``repro.lint``'s dtype-promotion pass)."""
    flat = norm_scores.reshape(-1).astype(jnp.float32)
    thresholds = jnp.asarray(thresholds, jnp.float32)
    return jax.vmap(lambda t: jnp.mean(flat <= t, dtype=jnp.float32))(
        thresholds)


def calibrate_threshold(norm_scores, target_drop_rate: float):
    """Inverse of the threshold->drop-rate map: the T¹ achieving a target
    drop rate on calibration scores (the 'tailored mapping between threshold
    and drop rate' the paper calls for in §5.3.3). Returns an f32 scalar
    (explicitly — no x64-dependent promotion)."""
    flat = jnp.sort(norm_scores.reshape(-1).astype(jnp.float32))
    n = flat.shape[0]
    frac = jnp.asarray(target_drop_rate, jnp.float32)
    idx = jnp.clip(jnp.floor(frac * n).astype(jnp.int32), 0, n - 1)
    return flat[idx]


def calibrate_per_layer_thresholds(layer_norm_scores, target_drop_rate: float,
                                   gap: float = 0.01):
    """Beyond-paper (the paper's stated future work, §5.3.3): per-layer
    (T²_major, T²_minor) pairs that equalize each layer's drop rate at the
    target — Fig 12 shows the same threshold drops 3x more in deep layers
    than shallow ones, so a global T over-drops exactly where sensitivity is
    highest.

    layer_norm_scores: list of (N,K) calibration scores, one per layer.
    Returns (L, 2) array of [t_major, t_minor] rows."""
    gap = jnp.float32(gap)
    ts = jnp.stack([calibrate_threshold(s, target_drop_rate)
                    for s in layer_norm_scores])
    return jnp.stack([jnp.maximum(ts - gap, jnp.float32(0.0)), ts + gap],
                     axis=1)
