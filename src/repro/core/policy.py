"""First-class sparsity policies: ONE pluggable object per deployment
scenario instead of scattered booleans.

A ``SparsityPolicy`` owns the three coupled decisions the paper makes:

  (a) **param preparation** — ``prepare(params, cfg, calib_x)``: partial
      transformation factor, neuron-importance reconstruction, and threshold
      calibration (absorbing ``transform_params_for_dualsparse``);
  (b) **routing** — ``route(params, x, cfg, *, loads=None)``: which
      token/(sub-)expert pairs to compute (absorbing the
      ``route_plain`` / ``route_dualsparse`` / ``expand_pairs_*`` selection
      and the ``params["thresholds"]`` side-channel);
  (c) **execution hints** — kernel choice, dispatch capacity factor, and
      exact-capacity mode for batch-composition-invariant serving.

Policies are frozen dataclasses registered as JAX pytrees: threshold
*values* are leaves (so a policy can be passed as a jit argument and its
values changed per call — or per request/slot — without retracing), while
structural knobs (partition factor, importance metric, kernel/capacity
hints) are static aux data. The registry maps CLI names to classes:

    none | 1t | 2t | load_aware | per_layer

Everything downstream — ``DistContext``, ``setp_moe_forward``, the model's
``_moe_forward``, both serving engines, the launchers, and the benchmarks —
consumes policies instead of booleans.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp

from . import drop as drop_mod
from . import gating
from . import moe as moe_mod


# ---------------------------------------------------------------------------
# Pytree registration: dynamic (threshold) fields are leaves, the rest aux
# ---------------------------------------------------------------------------

POLICIES: Dict[str, Type["SparsityPolicy"]] = {}


def register_policy(name: str):
    """Class decorator: register under ``name`` and make the class a pytree
    whose ``_dynamic`` fields are children (traced) and whose remaining
    dataclass fields are static aux data (retrace on change)."""
    def deco(cls):
        cls.name = name
        POLICIES[name] = cls
        dyn = tuple(cls._dynamic)
        static = tuple(f.name for f in dataclasses.fields(cls)
                       if f.name not in dyn)
        # introspection hooks for repro.lint's retrace-hazard pass: the
        # exact field partition the pytree flatten uses
        cls._pytree_dynamic = dyn
        cls._pytree_static = static

        def flatten(p):
            return (tuple(getattr(p, n) for n in dyn),
                    tuple(getattr(p, n) for n in static))

        def unflatten(aux, children):
            kw = dict(zip(static, aux))
            kw.update(zip(dyn, children))
            return cls(**kw)

        jax.tree_util.register_pytree_node(cls, flatten, unflatten)
        return cls
    return deco


def _bt(t, score):
    """Broadcast a threshold against a (T, K') score block: scalars pass
    through, per-token (T,) vectors gain a pair axis."""
    t = jnp.asarray(t)
    return t[:, None] if t.ndim == 1 else t


# ---------------------------------------------------------------------------
# Base class
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SparsityPolicy:
    """Base policy. Subclasses list their traced fields in ``_dynamic``."""

    # --- static structure (pytree aux data) ---
    partition_p: int = 1            # partial-transformation factor P
    importance: str = "abs_gate"    # neuron-importance metric (§4.2b)
    reconstruction: bool = True     # reorder neurons before partition
    # --- execution hints (static) ---
    use_kernel: bool = False        # Pallas grouped kernel on expert GEMMs
    fused_pipeline: Optional[bool] = None   # single fused (streamed) Pallas
    #                                 dispatch->FFN->combine kernel (no
    #                                 (E, C, d) HBM buffer, no unpermute
    #                                 read-back). None = auto: resolved per
    #                                 shape/backend at trace time by
    #                                 core.dispatch.prefer_fused_pipeline
    #                                 (TPU/GPU: always fused; CPU interpret:
    #                                 fused iff use_kernel). True/False
    #                                 force the choice.
    capacity_factor: float = 2.0    # dispatch-path expert capacity factor
    exact_capacity: bool = False    # capacity = T: no overflow drop ever,
    #                                 so MoE outputs are batch-invariant
    drop_target: Optional[float] = None   # calibrate thresholds in prepare()

    _dynamic: Tuple[str, ...] = ()
    name = "base"
    needs_loads = False             # setp body must psum a load histogram

    @property
    def kernel_mode_grouping(self) -> bool:
        """Execution hint: with ``use_kernel`` on the dispatch path, group
        pairs by ORIGINAL expert in mode order (FULL rows first, MAJOR-only
        rows second) so ``counts_major`` reaches the dual-sparse kernel and
        minor-half MXU tiles are skipped (paper §4.2). Sound for any policy
        whose keep mask is mode-monotone (a kept minor half implies a kept
        major half) — true of every registered drop policy."""
        return self.partition_p > 1

    # -- (a) param preparation ------------------------------------------

    def prepare_layer(self, moe_params: Dict, cfg, calib_x=None, *,
                      n_ep_devices: int = 0) -> Dict:
        """One MoE layer's param dict -> prepared dict (partition +
        reconstruction + strided EP placement)."""
        out = moe_params
        if self.partition_p > 1:
            if calib_x is None:
                raise ValueError(f"{self.name}: prepare needs calibration "
                                 "activations to profile neuron importance")
            if self.reconstruction:
                from . import reconstruct
                out = reconstruct.partition_and_reconstruct(
                    out, calib_x, cfg, p=self.partition_p,
                    method=self.importance)
            else:
                from . import partition
                out = partition.partial_transform(out, self.partition_p)
        if n_ep_devices:
            from . import setp
            out = setp.place_params_strided(out, n_ep_devices)
        return out

    def prepare(self, params: Dict, cfg, calib_x=None, *,
                n_ep_devices: int = 0) -> Tuple[Dict, "SparsityPolicy"]:
        """Prepare a full model param tree (or a bare MoE layer dict).

        Returns ``(prepared_params, calibrated_policy)`` — the returned
        policy has thresholds calibrated to ``drop_target`` when set."""
        if "blocks" in params:
            blocks = params["blocks"]
            if "moe" not in blocks:
                return params, self
            new_moe = jax.vmap(lambda mp: self.prepare_layer(
                mp, cfg, calib_x, n_ep_devices=n_ep_devices))(blocks["moe"])
            out = dict(params)
            out["blocks"] = {**blocks, "moe": new_moe}
            wg = new_moe["wg"]                          # (L, d, E)
            return out, self._calibrated(wg, cfg, calib_x)
        if "wg" not in params:
            return params, self
        new = self.prepare_layer(params, cfg, calib_x,
                                 n_ep_devices=n_ep_devices)
        return new, self._calibrated(new["wg"][None], cfg, calib_x)

    def _calib_scores(self, wg_stack, cfg, calib_x):
        """Pooled normalized gating scores over all layers' routers."""
        def one(wg):
            return gating.route(calib_x, wg, cfg.top_k,
                                cfg.router_norm_topk).norm_score
        return jax.vmap(one)(wg_stack)

    def _calibrated(self, wg_stack, cfg, calib_x) -> "SparsityPolicy":
        """Override in subclasses that support ``drop_target``."""
        return self

    def calibrate(self, prepared_params: Dict, cfg,
                  calib_x) -> "SparsityPolicy":
        """Calibrate this policy's thresholds to ``drop_target`` against
        already-prepared params, WITHOUT re-running the (expensive) param
        preparation — for sweeping thresholds over one prepared model."""
        if "blocks" in prepared_params:
            wg = prepared_params["blocks"]["moe"]["wg"]
        else:
            wg = prepared_params["wg"][None]
        return self._calibrated(wg, cfg, calib_x)

    # -- (b) routing -----------------------------------------------------

    def route(self, params: Dict, x, cfg, *,
              loads=None) -> drop_mod.SubExpertPairs:
        raise NotImplementedError

    def sub_pair_keep(self, score, is_major, sub_idx, cfg, *, n_dev: int = 1,
                      loads=None, thresholds=None):
        """Keep mask over already-expanded (T, K*P) sub-expert pairs — the
        form the S-ETP shard_map body needs (it expands routing itself so
        the AlltoAll layout stays fused). ``loads``: (n_dev,) pre-drop
        histogram when ``needs_loads``; ``thresholds``: per-layer (2,)
        calibrated pair when the params carry one."""
        raise NotImplementedError

    # -- helpers ---------------------------------------------------------

    def per_token(self, batch: int, seq: int) -> "SparsityPolicy":
        """Expand per-row (B,) threshold leaves to per-token (B*S,) so a
        per-slot/per-request policy broadcasts over a flattened (B*S, d)
        token block. Scalar leaves pass through."""
        if seq == 1:
            return self

        def f(leaf):
            a = jnp.asarray(leaf)
            return jnp.repeat(a, seq) if a.ndim == 1 else leaf
        return jax.tree_util.tree_map(f, self)

    def dispatch_capacity(self, n_tokens: int) -> Optional[int]:
        """Exact-capacity hint: pin dispatch capacity to the token count so
        no pair can overflow-drop (each token selects a sub-expert at most
        once, so capacity == T is always sufficient)."""
        return n_tokens if self.exact_capacity else None


# ---------------------------------------------------------------------------
# Concrete policies
# ---------------------------------------------------------------------------

@register_policy("none")
@dataclasses.dataclass(frozen=True)
class NoDrop(SparsityPolicy):
    """No partition, no dropping: the plain top-k MoE layer."""
    partition_p: int = 1
    _dynamic: Tuple[str, ...] = ()

    def route(self, params, x, cfg, *, loads=None):
        return moe_mod.route_plain(params, x, cfg)

    def sub_pair_keep(self, score, is_major, sub_idx, cfg, *, n_dev=1,
                      loads=None, thresholds=None):
        return jnp.ones_like(score, dtype=bool)

    @classmethod
    def from_config(cls, ds, drop_target=None, **kw):
        return cls(**kw)


@register_policy("1t")
@dataclasses.dataclass(frozen=True)
class OneTDrop(SparsityPolicy):
    """1T-Drop (§4.1): drop a token-expert pair entirely when its normalized
    gating score is below T¹ — with partition, both halves go together."""
    partition_p: int = 2
    t_drop: float = 0.08
    _dynamic: Tuple[str, ...] = ("t_drop",)

    def route(self, params, x, cfg, *, loads=None):
        r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
        return drop_mod.expand_pairs_1t(r.idx, r.combine, r.norm_score,
                                        self.partition_p, self.t_drop)

    def sub_pair_keep(self, score, is_major, sub_idx, cfg, *, n_dev=1,
                      loads=None, thresholds=None):
        return score > _bt(self.t_drop, score)

    def _calibrated(self, wg_stack, cfg, calib_x):
        if self.drop_target is None:
            return self
        scores = self._calib_scores(wg_stack, cfg, calib_x)
        t = drop_mod.calibrate_threshold(scores, self.drop_target)
        return dataclasses.replace(self, t_drop=float(t))

    @classmethod
    def from_config(cls, ds, drop_target=None, **kw):
        return cls(partition_p=ds.partition_p, importance=ds.importance,
                   t_drop=ds.t_drop, drop_target=drop_target, **kw)


@register_policy("2t")
@dataclasses.dataclass(frozen=True)
class TwoTDrop(SparsityPolicy):
    """2T-Drop (§4.2): below T²_major drop both halves, between compute the
    reconstructed MAJOR half only, above T²_minor compute the full expert."""
    partition_p: int = 2
    t_major: float = 0.07
    t_minor: float = 0.09
    _dynamic: Tuple[str, ...] = ("t_major", "t_minor")

    def _pair_thresholds(self, r, params, cfg, loads):
        return self.t_major, self.t_minor

    def route(self, params, x, cfg, *, loads=None):
        r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
        tm, tn = self._pair_thresholds(r, params, cfg, loads)
        return drop_mod.expand_pairs_2t(r.idx, r.combine, r.norm_score,
                                        self.partition_p, tm, tn)

    def sub_pair_keep(self, score, is_major, sub_idx, cfg, *, n_dev=1,
                      loads=None, thresholds=None):
        # strict > on BOTH thresholds (matching one_t_keep's boundary), so
        # t_major == t_minor degenerates 2T -> 1T exactly, incl. at the
        # boundary score.
        return jnp.where(is_major, score > _bt(self.t_major, score),
                         score > _bt(self.t_minor, score))

    def _calibrated(self, wg_stack, cfg, calib_x, delta: float = 0.05):
        if self.drop_target is None:
            return self
        # calibrate in RATE space (band = ±delta drop rate around the
        # target) so flops saved == target regardless of the score spread:
        # saved = (t-δ) + ½·2δ = target.
        scores = self._calib_scores(wg_stack, cfg, calib_x)
        tm = drop_mod.calibrate_threshold(
            scores, max(self.drop_target - delta, 0.0))
        tn = drop_mod.calibrate_threshold(
            scores, min(self.drop_target + delta, 1.0))
        return dataclasses.replace(self, t_major=float(tm), t_minor=float(tn))

    @classmethod
    def from_config(cls, ds, drop_target=None, **kw):
        return cls(partition_p=ds.partition_p, importance=ds.importance,
                   t_major=ds.t_major, t_minor=ds.t_minor,
                   drop_target=drop_target, **kw)


@register_policy("load_aware")
@dataclasses.dataclass(frozen=True)
class LoadAwareTwoT(SparsityPolicy):
    """2T-Drop with load-aware thresholding (§4.3): each EP device's
    threshold steps down with its load ratio, so lightly-loaded devices
    drop less — the makespan (max device load) sets the step time anyway.

    ``n_devices`` models the EP layout on the single-device dispatch path
    (contiguous expert blocks, as in ``core.load_aware``); the S-ETP body
    passes its real strided device mapping instead. With ``loads`` uniform
    (or ``n_devices == 1``) this is exactly ``TwoTDrop(t_max - t_gap,
    t_max + t_gap)``."""
    partition_p: int = 2
    n_devices: int = 1
    t_max: float = 0.12
    t_gap: float = 0.01
    _dynamic: Tuple[str, ...] = ("t_max", "t_gap")
    needs_loads = True

    def _t1(self, score, loads, dev_of):
        """Per-pair stepped-down T¹ = t_max * min(load_ratio, 1)[device]."""
        loads = loads.astype(jnp.float32)
        ratio = loads / jnp.maximum(jnp.mean(loads), 1e-9)
        factor = jnp.minimum(ratio, 1.0)
        return _bt(self.t_max, score) * factor[dev_of]

    def route(self, params, x, cfg, *, loads=None):
        r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
        E = params["wg"].shape[1]
        per_dev = max(E // self.n_devices, 1)
        if loads is None:
            hist = gating.expert_histogram(r.idx, E)
            from . import load_aware
            loads = load_aware.device_loads(hist, per_dev)
        t1 = self._t1(r.norm_score, loads, r.idx // per_dev)
        gap = _bt(self.t_gap, r.norm_score)
        return drop_mod.expand_pairs_2t(
            r.idx, r.combine, r.norm_score, self.partition_p,
            jnp.maximum(t1 - gap, 0.0), t1 + gap)

    def sub_pair_keep(self, score, is_major, sub_idx, cfg, *, n_dev=1,
                      loads=None, thresholds=None):
        if loads is None:
            raise ValueError("LoadAwareTwoT.sub_pair_keep needs the psum'd "
                             "per-device load histogram")
        t1 = self._t1(score, loads, sub_idx % n_dev)   # strided placement
        gap = _bt(self.t_gap, score)
        return jnp.where(is_major, score > jnp.maximum(t1 - gap, 0.0),
                         score > t1 + gap)

    @classmethod
    def from_config(cls, ds, drop_target=None, **kw):
        return cls(partition_p=ds.partition_p, importance=ds.importance,
                   t_max=ds.t_max, t_gap=(ds.t_minor - ds.t_major) / 2,
                   drop_target=drop_target, **kw)


@register_policy("per_layer")
@dataclasses.dataclass(frozen=True)
class PerLayerCalibrated2T(SparsityPolicy):
    """Beyond-paper (§5.3.3 future work): per-layer (T²_major, T²_minor)
    calibrated so EVERY layer hits ``drop_target`` on its own router's
    score distribution (Fig 12: a global T over-drops in deep layers).
    Thresholds live in the param tree as ``moe["thresholds"]`` (2,) per
    layer, so layer scans slice them automatically."""
    partition_p: int = 2
    drop_target: Optional[float] = 0.25
    delta: float = 0.05
    _dynamic: Tuple[str, ...] = ()

    def prepare_layer(self, moe_params, cfg, calib_x=None, *,
                      n_ep_devices: int = 0):
        out = super().prepare_layer(moe_params, cfg, calib_x,
                                    n_ep_devices=n_ep_devices)
        r = gating.route(calib_x, moe_params["wg"], cfg.top_k,
                         cfg.router_norm_topk)
        target = self.drop_target if self.drop_target is not None else 0.25
        tm = drop_mod.calibrate_threshold(
            r.norm_score, max(target - self.delta, 0.0))
        tn = drop_mod.calibrate_threshold(
            r.norm_score, min(target + self.delta, 1.0))
        out = dict(out)
        out["thresholds"] = jnp.stack([tm, tn])
        return out

    def _layer_thresholds(self, params=None, thresholds=None):
        th = thresholds if thresholds is not None else \
            (params or {}).get("thresholds")
        if th is None:
            raise ValueError("per_layer policy: params carry no "
                             "'thresholds' — run policy.prepare() first")
        return th[0], th[1]

    def route(self, params, x, cfg, *, loads=None):
        r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
        tm, tn = self._layer_thresholds(params)
        return drop_mod.expand_pairs_2t(r.idx, r.combine, r.norm_score,
                                        self.partition_p, tm, tn)

    def sub_pair_keep(self, score, is_major, sub_idx, cfg, *, n_dev=1,
                      loads=None, thresholds=None):
        tm, tn = self._layer_thresholds(thresholds=thresholds)
        return jnp.where(is_major, score > tm, score > tn)

    @classmethod
    def from_config(cls, ds, drop_target=None, **kw):
        return cls(partition_p=ds.partition_p, importance=ds.importance,
                   drop_target=0.25 if drop_target is None else drop_target,
                   **kw)


# ---------------------------------------------------------------------------
# Registry helpers
# ---------------------------------------------------------------------------

def make_policy(name: str, ds=None, *, drop_target: Optional[float] = None,
                **kw) -> SparsityPolicy:
    """Build a registered policy from a ``DualSparseConfig`` (or defaults).

    ``name``: none | 1t | 2t | load_aware | per_layer. Extra kwargs
    (``use_kernel=``, ``exact_capacity=``, ...) override execution hints."""
    if name not in POLICIES:
        raise KeyError(f"unknown sparsity policy {name!r}; registered: "
                       f"{sorted(POLICIES)}")
    if ds is None:
        from ..configs.base import DualSparseConfig
        ds = DualSparseConfig()
    return POLICIES[name].from_config(ds, drop_target=drop_target, **kw)


def default_policy() -> SparsityPolicy:
    return NoDrop()


def registered_policies() -> Dict[str, Type[SparsityPolicy]]:
    """Snapshot of the policy registry (name -> class). ``repro.lint``
    iterates this to audit every policy's static/traced field split."""
    return dict(POLICIES)
