"""MoE layer: params, exact dense reference, and the capacity-based
dispatch path used inside jit/shard_map.

Three forward paths, all fixed-shape / jit-safe:

  * ``moe_forward_ref``       — computes every expert for every token and
    combines with (possibly dropped) weights. Exact oracle, O(T·E) compute.
  * ``moe_forward_dispatch``  — sort-based capacity dispatch
    (``core.dispatch``): gather tokens into (E, C, d) buffers in
    mode-ordered arrival order, batched expert GEMMs, gather back. This is
    the per-device body of S-ETP and the host of the Pallas kernel; under a
    partitioned drop policy with ``use_kernel`` it groups by ORIGINAL
    expert so the dual-sparse kernel skips minor-half MXU tiles.
  * shard_map S-ETP lives in ``core.setp``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models.layers import normal
from . import dispatch as dispatch_mod
from . import gating
from .drop import SubExpertPairs, expand_pairs_2t, MODE_FULL


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def make_moe_params(key, cfg, d_expert: Optional[int] = None,
                    n_experts: Optional[int] = None):
    """Param tree (wrapped in Param leaves with logical axes)."""
    d = cfg.d_model
    E = n_experts if n_experts is not None else cfg.n_experts
    f = d_expert if d_expert is not None else cfg.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "wg": normal(ks[0], (d, E), ("embed", None)),
        "w1": normal(ks[1], (E, d, f), ("expert", "embed", "expert_ffn")),
        "w3": normal(ks[2], (E, d, f), ("expert", "embed", "expert_ffn")),
        "w2": normal(ks[3], (E, f, d), ("expert", "expert_ffn", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        km = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w1": normal(km[0], (d, fs), ("embed", "ffn")),
            "w3": normal(km[1], (d, fs), ("embed", "ffn")),
            "w2": normal(km[2], (fs, d), ("ffn", "embed")),
        }
    return p


def expert_ffn(w1, w3, w2, x):
    """Batched SwiGLU over experts: x (E, C, d) -> (E, C, d)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w1))
    h = h * jnp.einsum("ecd,edf->ecf", x, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _shared_out(params, x):
    if "shared" not in params:
        return 0.0
    s = params["shared"]
    h = jax.nn.silu(x @ s["w1"]) * (x @ s["w3"])
    return h @ s["w2"]


# ---------------------------------------------------------------------------
# Routing helpers
# ---------------------------------------------------------------------------

def route_dualsparse(params, x, cfg, *, thresholds=None) -> SubExpertPairs:
    """Routing incl. partial-transformation expansion and 2T-Drop keep mask.

    ``thresholds``: optional (t_major, t_minor) override — each entry may be
    scalar or per-token (T,) for load-aware thresholding.
    Requires params already partial-transformed with cfg.dualsparse.partition_p.
    """
    ds = cfg.dualsparse
    r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
    if thresholds is not None:
        t_major, t_minor = thresholds
    elif "thresholds" in params:
        # per-layer calibrated thresholds (beyond-paper, §5.3.3 future work);
        # stored in the param tree so layer scans slice them automatically
        t_major, t_minor = params["thresholds"][0], params["thresholds"][1]
    else:
        t_major, t_minor = ds.t_major, ds.t_minor
    return expand_pairs_2t(r.idx, r.combine, r.norm_score,
                           ds.partition_p, t_major, t_minor)


def aux_loss_for(params, x, cfg):
    """Switch-style load-balance auxiliary loss for this MoE layer."""
    r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
    E = params["wg"].shape[1]
    return gating.load_balance_aux_loss(r.probs, r.idx, E)


def route_plain(params, x, cfg, n_experts=None) -> SubExpertPairs:
    """Routing with no partition/drop (P=1, keep everything)."""
    E = n_experts if n_experts is not None else params["wg"].shape[1]
    k = cfg.top_k if E == cfg.n_experts else cfg.top_k * (E // cfg.n_experts)
    r = gating.route(x, params["wg"], k, cfg.router_norm_topk)
    return SubExpertPairs(idx=r.idx, combine=r.combine,
                          keep=jnp.ones_like(r.idx, dtype=bool),
                          modes=jnp.full_like(r.idx, MODE_FULL))


# ---------------------------------------------------------------------------
# Reference forward (exact, dense over experts)
# ---------------------------------------------------------------------------

def moe_forward_ref(params, x, cfg, pairs: Optional[SubExpertPairs] = None):
    """Dense oracle: every expert computed for every token.

    x: (T, d). If ``pairs`` is given, combine weights/keep masks come from it
    (sub-expert ids index params' expert axis).
    """
    E = params["w1"].shape[0]
    if pairs is None:
        pairs = route_plain(params, x, cfg, n_experts=E)
    # all-expert outputs: (E, T, d)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", x, params["w1"]))
    h = h * jnp.einsum("td,edf->etf", x, params["w3"])
    outs = jnp.einsum("etf,efd->etd", h, params["w2"])
    w = pairs.combine * pairs.keep.astype(pairs.combine.dtype)   # (T, K')
    sel = jax.nn.one_hot(pairs.idx, E, dtype=w.dtype) * w[..., None]
    y = jnp.einsum("tke,etd->td", sel, outs).astype(x.dtype)
    return y + _shared_out(params, x)


# ---------------------------------------------------------------------------
# Capacity-based dispatch forward (production per-device path)
# ---------------------------------------------------------------------------

def capacity_for(n_tokens: int, k_eff: int, n_experts: int,
                 capacity_factor: float = 1.25, multiple: int = 8) -> int:
    cap = int(capacity_factor * n_tokens * k_eff / n_experts)
    return max(multiple, (cap + multiple - 1) // multiple * multiple)


def dispatch_indices(pairs: SubExpertPairs, n_experts: int, capacity: int):
    """Compute per-pair (expert, slot) coordinates via the sort-based plan
    (``core.dispatch``). Dropped pairs and over-capacity pairs get
    slot == capacity (out of range, discarded).

    Returns ``(flat_e, slot, overflow)`` where ``overflow`` is the scalar
    count of KEPT pairs silently discarded because their expert's capacity
    was exhausted — the quantity a deployment must watch (an overflow drop
    is an accuracy loss the drop policy never sanctioned)."""
    plan = dispatch_mod.dispatch_plan(pairs.idx, pairs.keep,
                                      n_groups=n_experts, capacity=capacity)
    return plan.group, plan.slot, plan.overflow


def _pairs_partition_p(pairs: SubExpertPairs) -> int:
    """Partial-transformation factor encoded in an expanded pair list
    (``modes`` is per ORIGINAL pair, ``idx`` per sub-expert pair)."""
    Kp = pairs.idx.shape[1]
    K = pairs.modes.shape[1]
    return Kp // K if K and Kp % K == 0 else 1


def _sub_pair_overflow(plan, pairs: SubExpertPairs, fused, capacity: int):
    """Capacity-overflow drops of an ORIGINAL-expert (fused) plan counted in
    the canonical unit: SUB-expert pairs. A fused row stands for every kept
    half of its original pair (P when FULL, 1 when MAJOR-only), so counting
    overflowed fused rows 1:1 — as this path used to — under-reports by up
    to P-1 sub-pairs per drop and is incomparable with the sub-pair dispatch
    path and ``_setp_body`` (``engine.overflow_pairs`` mixes units)."""
    T, K = fused.group.shape
    p = pairs.idx.shape[1] // K
    kept_halves = pairs.keep.reshape(T, K, p).sum(-1).astype(jnp.int32)
    overflowed = fused.keep.reshape(-1) & (plan.slot.reshape(-1) >= capacity)
    return jnp.sum(jnp.where(overflowed, kept_halves.reshape(-1), 0))


def _fused_kernel_dispatch(params, x, cfg, pairs: SubExpertPairs, p: int,
                           capacity: int):
    """Original-expert-granularity dispatch for the dual-sparse kernel: one
    row per (token, ORIGINAL expert) pair — halving dispatched pairs at P=2
    — mode-ordered FULL-first/MAJOR-only-second, with ``counts_major``
    driving the kernel's minor-half tile skipping (paper §4.2). Exact
    w.r.t. the sub-expert path under partial transformation (Eq. 13).
    Overflow is reported in SUB-pair units (see ``_sub_pair_overflow``)."""
    from ..kernels import ops as kops
    T, d = x.shape
    E = params["w1"].shape[0] // p
    fused = dispatch_mod.fuse_sub_pairs(pairs, p)
    K = fused.group.shape[1]
    plan = dispatch_mod.dispatch_plan(fused.group, fused.keep,
                                      n_groups=E, capacity=capacity,
                                      major_only=fused.major_only)
    buf = dispatch_mod.gather_rows(x, plan, capacity, index_div=K)
    cf, cm = plan.kernel_counts(capacity)
    out_buf = kops.grouped_swiglu(buf, params["w1"], params["w3"],
                                  params["w2"], counts_full=cf,
                                  counts_major=cm, p_factor=p)
    gathered = dispatch_mod.unpermute(out_buf, plan)            # (T*K, d)
    w = (fused.combine * fused.keep.astype(fused.combine.dtype)).reshape(-1)
    y = (gathered * w[:, None].astype(gathered.dtype))
    overflow = _sub_pair_overflow(plan, pairs, fused, capacity)
    return y.reshape(T, K, d).sum(axis=1), overflow


def _fused_pipeline_block(block_c: int, capacity: int) -> int:
    return min(block_c, capacity)


def _fused_pipeline_dispatch(params, x, cfg, pairs: SubExpertPairs, p: int,
                             capacity: int, mode_grouped: bool,
                             block_c: int = 128, block_f: int = 128,
                             streamed: bool = True):
    """The single fused Pallas pipeline (ROADMAP item 4): the kernel
    consumes the DispatchPlan directly — sort permutation + segment counts
    — gathering token rows from the flat (T, d) array, running the
    mode-ordered grouped SwiGLU with minor-half tile skipping, and
    scatter-accumulating combine-weighted outputs per token. Eliminates
    both HBM round-trips of the buffer path (the gather-built
    (E, capacity, d) buffer the kernel re-reads, and the unpermute
    read-back); that path remains as the bit-exactness oracle.

    ``mode_grouped`` (P > 1): one row per ORIGINAL pair, weights fused at
    kernel level via ``p_factor`` BlockSpec indexing. Otherwise rows are
    sub-expert pairs against the weights' native expert axis. Overflow is
    reported in SUB-pair units on both layouts."""
    from ..kernels import ops as kops
    T, d = x.shape
    bc = _fused_pipeline_block(block_c, capacity)
    if mode_grouped and p > 1:
        E = params["w1"].shape[0] // p
        fused = dispatch_mod.fuse_sub_pairs(pairs, p)
        K = fused.group.shape[1]
        plan = dispatch_mod.dispatch_plan(fused.group, fused.keep,
                                          n_groups=E, capacity=capacity,
                                          major_only=fused.major_only)
        w = fused.combine * fused.keep.astype(fused.combine.dtype)
        overflow = _sub_pair_overflow(plan, pairs, fused, capacity)
        p_factor, n_minor_start = p, None
    else:
        E = params["w1"].shape[0]
        K = pairs.idx.shape[1]
        plan = dispatch_mod.dispatch_plan(pairs.idx, pairs.keep,
                                          n_groups=E, capacity=capacity)
        w = pairs.combine * pairs.keep.astype(pairs.combine.dtype)
        overflow = plan.overflow
        p_factor, n_minor_start = 1, params["w1"].shape[-1]
    tok_sorted, w_sorted = dispatch_mod.sorted_pair_arrays(
        plan, w, index_div=K, pad=bc)
    cf, cm = plan.kernel_counts(capacity)
    y = kops.fused_moe_pipeline(
        x, params["w1"], params["w3"], params["w2"], plan.group_offsets,
        cf, cm, tok_sorted, w_sorted, capacity=capacity, p_factor=p_factor,
        n_minor_start=n_minor_start, block_c=block_c, block_f=block_f,
        streamed=streamed)
    return y, overflow


def moe_forward_dispatch(params, x, cfg, pairs: Optional[SubExpertPairs] = None,
                         capacity_factor: float = 1.25,
                         capacity: Optional[int] = None,
                         use_kernel: bool = False,
                         return_overflow: bool = False,
                         mode_grouped: bool = False,
                         fused_pipeline: Optional[bool] = None,
                         fused_streamed: bool = True):
    """Sort-based gather -> batched expert GEMM -> gather back. Exact w.r.t.
    the reference whenever no token exceeds capacity.

    With ``use_kernel`` the batched GEMM is the Pallas dualsparse kernel.
    Under a partitioned drop policy (P > 1), ``mode_grouped=True``
    (``SparsityPolicy.kernel_mode_grouping`` supplies it in production)
    additionally groups pairs by ORIGINAL expert so 2T-Drop's MAJOR-only
    rows sort after the FULL rows and ``counts_major`` lets the kernel skip
    minor-half MXU tiles — the §4.2 saving, live in production. Mode
    grouping requires a mode-monotone keep mask (a kept minor half implies
    a kept major half — true of every registered policy); it is opt-in
    (default off) so hand-built pair lists that violate the invariant keep
    the exact per-sub-pair semantics. Without the kernel a jnp einsum
    computes full sub-experts (minor-half skipping then only reduces
    *dispatched* pairs: the minor sub-expert of a mode-1 token is simply
    never dispatched).

    ``fused_pipeline`` (``SparsityPolicy.fused_pipeline`` supplies it in
    production) routes through the single fused streamed Pallas kernel —
    dispatch gather, grouped SwiGLU, and weighted combine in one launch,
    with no (E, capacity, d) HBM buffer and no unpermute read-back, and a
    VMEM working set independent of T (pair maps in scalar-prefetch SMEM,
    x/out in HBM behind double-buffered DMA). ``None`` (the default)
    resolves per shape/backend via
    ``core.dispatch.prefer_fused_pipeline`` — fused everywhere on
    TPU/GPU, fused iff ``use_kernel`` on CPU interpret. The buffer path
    below stays as its bit-exactness oracle. ``fused_streamed=False``
    selects the whole-array-resident kernel variant (identical math and
    accumulation order — bit-exact vs streamed; bench/debug knob only).

    ``return_overflow``: also return the scalar count of kept pairs dropped
    by capacity overflow (see ``dispatch_indices``). Always in sub-pair
    units, on every path.
    """
    T, d = x.shape
    E = params["w1"].shape[0]
    if pairs is None:
        pairs = route_plain(params, x, cfg, n_experts=E)
    K = pairs.idx.shape[1]
    if capacity is None:
        capacity = capacity_for(T, K, E, capacity_factor)

    p = _pairs_partition_p(pairs)
    if fused_pipeline is None:
        fused_pipeline = dispatch_mod.prefer_fused_pipeline(
            T, E, use_kernel=use_kernel)
    if fused_pipeline:
        y, overflow = _fused_pipeline_dispatch(
            params, x, cfg, pairs, p, capacity,
            mode_grouped=mode_grouped and p > 1, streamed=fused_streamed)
        out = y.astype(x.dtype) + _shared_out(params, x)
        return (out, overflow) if return_overflow else out

    if use_kernel and mode_grouped and p > 1:
        y, overflow = _fused_kernel_dispatch(params, x, cfg, pairs, p,
                                             capacity)
        out = y.astype(x.dtype) + _shared_out(params, x)
        return (out, overflow) if return_overflow else out

    plan = dispatch_mod.dispatch_plan(pairs.idx, pairs.keep,
                                      n_groups=E, capacity=capacity)
    buf = dispatch_mod.gather_rows(x, plan, capacity, index_div=K)

    if use_kernel:
        from ..kernels import ops as kops
        cf, cm = plan.kernel_counts(capacity)
        out_buf = kops.grouped_swiglu(buf, params["w1"], params["w3"],
                                      params["w2"], counts_full=cf,
                                      counts_major=cm,
                                      n_minor_start=params["w1"].shape[-1])
    else:
        out_buf = expert_ffn(params["w1"], params["w3"], params["w2"], buf)

    gathered = dispatch_mod.unpermute(out_buf, plan)            # (T*K, d)
    w = (pairs.combine * pairs.keep.astype(pairs.combine.dtype)).reshape(-1)
    y = (gathered * w[:, None].astype(gathered.dtype))
    y = y.reshape(T, K, d).sum(axis=1)
    out = y.astype(x.dtype) + _shared_out(params, x)
    return (out, plan.overflow) if return_overflow else out
