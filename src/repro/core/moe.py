"""MoE layer: params, exact dense reference, and the capacity-based
gather/scatter dispatch path used inside jit/shard_map.

Three forward paths, all fixed-shape / jit-safe:

  * ``moe_forward_ref``       — computes every expert for every token and
    combines with (possibly dropped) weights. Exact oracle, O(T·E) compute.
  * ``moe_forward_dispatch``  — sort-free capacity dispatch: scatter tokens
    into an (E, C, d) buffer, batched expert GEMMs, scatter back. This is
    the per-device body of S-ETP and the host of the Pallas kernel.
  * shard_map S-ETP lives in ``core.setp``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models.layers import Param, normal
from . import gating
from .drop import SubExpertPairs, expand_pairs_2t, MODE_FULL


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def make_moe_params(key, cfg, d_expert: Optional[int] = None,
                    n_experts: Optional[int] = None):
    """Param tree (wrapped in Param leaves with logical axes)."""
    d = cfg.d_model
    E = n_experts if n_experts is not None else cfg.n_experts
    f = d_expert if d_expert is not None else cfg.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "wg": normal(ks[0], (d, E), ("embed", None)),
        "w1": normal(ks[1], (E, d, f), ("expert", "embed", "expert_ffn")),
        "w3": normal(ks[2], (E, d, f), ("expert", "embed", "expert_ffn")),
        "w2": normal(ks[3], (E, f, d), ("expert", "expert_ffn", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        km = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w1": normal(km[0], (d, fs), ("embed", "ffn")),
            "w3": normal(km[1], (d, fs), ("embed", "ffn")),
            "w2": normal(km[2], (fs, d), ("ffn", "embed")),
        }
    return p


def expert_ffn(w1, w3, w2, x):
    """Batched SwiGLU over experts: x (E, C, d) -> (E, C, d)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w1))
    h = h * jnp.einsum("ecd,edf->ecf", x, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _shared_out(params, x):
    if "shared" not in params:
        return 0.0
    s = params["shared"]
    h = jax.nn.silu(x @ s["w1"]) * (x @ s["w3"])
    return h @ s["w2"]


# ---------------------------------------------------------------------------
# Routing helpers
# ---------------------------------------------------------------------------

def route_dualsparse(params, x, cfg, *, thresholds=None) -> SubExpertPairs:
    """Routing incl. partial-transformation expansion and 2T-Drop keep mask.

    ``thresholds``: optional (t_major, t_minor) override — each entry may be
    scalar or per-token (T,) for load-aware thresholding.
    Requires params already partial-transformed with cfg.dualsparse.partition_p.
    """
    ds = cfg.dualsparse
    r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
    if thresholds is not None:
        t_major, t_minor = thresholds
    elif "thresholds" in params:
        # per-layer calibrated thresholds (beyond-paper, §5.3.3 future work);
        # stored in the param tree so layer scans slice them automatically
        t_major, t_minor = params["thresholds"][0], params["thresholds"][1]
    else:
        t_major, t_minor = ds.t_major, ds.t_minor
    return expand_pairs_2t(r.idx, r.combine, r.norm_score,
                           ds.partition_p, t_major, t_minor)


def aux_loss_for(params, x, cfg):
    """Switch-style load-balance auxiliary loss for this MoE layer."""
    r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
    E = params["wg"].shape[1]
    return gating.load_balance_aux_loss(r.probs, r.idx, E)


def route_plain(params, x, cfg, n_experts=None) -> SubExpertPairs:
    """Routing with no partition/drop (P=1, keep everything)."""
    E = n_experts if n_experts is not None else params["wg"].shape[1]
    k = cfg.top_k if E == cfg.n_experts else cfg.top_k * (E // cfg.n_experts)
    r = gating.route(x, params["wg"], k, cfg.router_norm_topk)
    return SubExpertPairs(idx=r.idx, combine=r.combine,
                          keep=jnp.ones_like(r.idx, dtype=bool),
                          modes=jnp.full_like(r.idx, MODE_FULL))


# ---------------------------------------------------------------------------
# Reference forward (exact, dense over experts)
# ---------------------------------------------------------------------------

def moe_forward_ref(params, x, cfg, pairs: Optional[SubExpertPairs] = None):
    """Dense oracle: every expert computed for every token.

    x: (T, d). If ``pairs`` is given, combine weights/keep masks come from it
    (sub-expert ids index params' expert axis).
    """
    E = params["w1"].shape[0]
    if pairs is None:
        pairs = route_plain(params, x, cfg, n_experts=E)
    # all-expert outputs: (E, T, d)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", x, params["w1"]))
    h = h * jnp.einsum("td,edf->etf", x, params["w3"])
    outs = jnp.einsum("etf,efd->etd", h, params["w2"])
    w = pairs.combine * pairs.keep.astype(pairs.combine.dtype)   # (T, K')
    sel = jax.nn.one_hot(pairs.idx, E, dtype=w.dtype) * w[..., None]
    y = jnp.einsum("tke,etd->td", sel, outs).astype(x.dtype)
    return y + _shared_out(params, x)


# ---------------------------------------------------------------------------
# Capacity-based dispatch forward (production per-device path)
# ---------------------------------------------------------------------------

def capacity_for(n_tokens: int, k_eff: int, n_experts: int,
                 capacity_factor: float = 1.25, multiple: int = 8) -> int:
    cap = int(capacity_factor * n_tokens * k_eff / n_experts)
    return max(multiple, (cap + multiple - 1) // multiple * multiple)


def dispatch_indices(pairs: SubExpertPairs, n_experts: int, capacity: int):
    """Compute per-pair (expert, slot) coordinates. Dropped pairs and
    over-capacity pairs get slot == capacity (out of range, discarded).

    Returns ``(flat_e, slot, overflow)`` where ``overflow`` is the scalar
    count of KEPT pairs silently discarded because their expert's capacity
    was exhausted — the quantity a deployment must watch (an overflow drop
    is an accuracy loss the drop policy never sanctioned)."""
    T, K = pairs.idx.shape
    flat_e = pairs.idx.reshape(-1)
    flat_keep = pairs.keep.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    onehot = onehot * flat_keep[:, None].astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot                  # (T*K, E)
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    overflow = jnp.sum((flat_keep & (slot >= capacity)).astype(jnp.int32))
    slot = jnp.where(flat_keep, slot, capacity)
    slot = jnp.minimum(slot, capacity)                          # overflow drops
    return flat_e, slot, overflow


def moe_forward_dispatch(params, x, cfg, pairs: Optional[SubExpertPairs] = None,
                         capacity_factor: float = 1.25,
                         capacity: Optional[int] = None,
                         use_kernel: bool = False,
                         return_overflow: bool = False):
    """Scatter -> batched expert GEMM -> gather. Exact w.r.t. the reference
    whenever no token exceeds capacity.

    With ``use_kernel`` the batched GEMM is the Pallas dualsparse kernel
    (block-skips minor halves); otherwise a jnp einsum computes full experts
    (minor-half skipping then only reduces *dispatched* pairs, which is how
    2T-Drop still yields proportional savings on this path: the minor
    sub-expert of a mode-1 token is simply never dispatched).

    ``return_overflow``: also return the scalar count of kept pairs dropped
    by capacity overflow (see ``dispatch_indices``).
    """
    T, d = x.shape
    E = params["w1"].shape[0]
    if pairs is None:
        pairs = route_plain(params, x, cfg, n_experts=E)
    K = pairs.idx.shape[1]
    if capacity is None:
        capacity = capacity_for(T, K, E, capacity_factor)
    flat_e, slot, overflow = dispatch_indices(pairs, E, capacity)

    buf = jnp.zeros((E, capacity + 1, d), x.dtype)
    buf = buf.at[flat_e, slot].set(jnp.repeat(x, K, axis=0))
    buf = buf[:, :capacity]

    if use_kernel:
        from ..kernels import ops as kops
        counts = gating.expert_histogram(pairs.idx, E, keep=pairs.keep)
        out_buf = kops.grouped_swiglu(buf, params["w1"], params["w3"],
                                      params["w2"],
                                      counts_full=jnp.minimum(counts, capacity))
    else:
        out_buf = expert_ffn(params["w1"], params["w3"], params["w2"], buf)

    out_buf = jnp.pad(out_buf, ((0, 0), (0, 1), (0, 0)))
    gathered = out_buf[flat_e, slot]                            # (T*K, d)
    w = (pairs.combine * pairs.keep.astype(pairs.combine.dtype)).reshape(-1)
    y = (gathered * w[:, None].astype(gathered.dtype))
    y = y.reshape(T, K, d).sum(axis=1)
    out = y.astype(x.dtype) + _shared_out(params, x)
    return (out, overflow) if return_overflow else out
