"""Sort-based, mode-ordered MoE dispatch (the shared fast substrate).

Every capacity-dispatch site in this repo — ``moe_forward_dispatch``, the
S-ETP shard_map body (device-level and local-expert-level slotting), and the
ETP baseline — reduces to the same problem: seat N flat (token, group) pairs
into fixed ``(G, capacity)`` buffers, preserving arrival order, dropping
pairs the routing policy discarded and counting pairs that overflow their
group's capacity.

The historical implementation materialized a dense ``one_hot(group, G)``
matrix and ran a ``cumsum`` down the pair axis — O(N·G) memory traffic for
what is an argsort problem. This module replaces it:

  * **argsort** a composite key ``(group, is_major_only, arrival)``; JAX's
    sort is stable, so a key of just ``group*2 + is_major_only`` (dropped
    pairs pushed past every group) keeps arrival order within each bucket
    for free — same slots as the cumsum path, bit for bit.
  * per-bucket counts come from a ``segment_sum`` histogram (O(N)) and group
    start offsets from one tiny (G,) ``cumsum`` — no (N, G) intermediate.
  * buffers are built by **gather** straight from the token array through
    ``perm`` (``gather_rows``), eliminating both the ``jnp.repeat(x, K)``
    materialization and the scatter of the old path.

**Mode ordering** is what finally feeds the dual-sparse kernel: with 2T-Drop
(paper §4.2) a pair is either FULL (both halves) or MAJOR-only. Passing the
major-only flag as the middle key sorts each group's buffer FULL-rows-first /
MAJOR-only-rows-second *by construction*, which is exactly the row layout
``kernels.dualsparse_ffn`` requires to skip whole minor-half MXU tiles —
``counts_full`` / ``counts_major`` fall out of the same histogram.

``cumsum_dispatch`` keeps the dense one-hot reference as an oracle for the
equivalence tests and ``benchmarks/bench_dispatch.py``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class DispatchPlan(NamedTuple):
    """Seating plan for N flat pairs into (G, capacity) buffers.

    All per-pair arrays are in the ORIGINAL flat-pair order; ``perm`` /
    ``group_offsets`` describe the sorted (buffer) order.
    """
    perm: jax.Array           # (N,) flat-pair ids in buffer order:
    #                           grouped by group, FULL rows first, then
    #                           MAJOR-only rows, then all dropped pairs
    group_offsets: jax.Array  # (G,) start of each group's run inside perm
    counts_full: jax.Array    # (G,) kept FULL-mode rows per group (unclamped)
    counts_major: jax.Array   # (G,) kept MAJOR-only rows per group
    group: jax.Array          # (N,) destination group (clipped to [0, G))
    slot: jax.Array           # (N,) buffer row; == capacity when the pair is
    #                           dropped (by policy or by capacity overflow)
    overflow: jax.Array       # ()  kept pairs discarded by capacity overflow

    @property
    def counts(self) -> jax.Array:
        """Kept rows per group (FULL + MAJOR-only), unclamped."""
        return self.counts_full + self.counts_major

    def kernel_counts(self, capacity: int):
        """(counts_full, counts_major) clamped so full+major <= capacity —
        the row-validity arrays ``kernels.ops.grouped_swiglu`` consumes."""
        cf = jnp.minimum(self.counts_full, capacity)
        total = jnp.minimum(self.counts_full + self.counts_major, capacity)
        return cf, total - cf


def group_histogram(ids, n_groups: int, *, mask=None, dtype=jnp.int32):
    """O(N) histogram of ``ids`` over [0, n_groups) via segment_sum —
    replaces the dense ``one_hot(ids, G).sum(...)`` hot spots. ``mask``
    drops pairs (their id value may then be arbitrary, even negative)."""
    flat = ids.reshape(-1)
    if mask is not None:
        flat = jnp.where(mask.reshape(-1), flat, n_groups)
    data = jnp.ones(flat.shape, dtype)
    return jax.ops.segment_sum(data, flat, num_segments=n_groups + 1,
                               indices_are_sorted=False)[:n_groups]


def sort_dispatch(group, keep=None, *, n_groups: int, capacity: int,
                  major_only=None) -> DispatchPlan:
    """Build a DispatchPlan by stable argsort of ``(group, mode, arrival)``.

    group: (N,) destination group per flat pair (values outside [0, G) are
        tolerated only where ``keep`` is False).
    keep: (N,) bool — pairs the routing policy kept (None = all).
    major_only: (N,) bool — kept pairs that compute only the MAJOR neuron
        half (2T mode 1); they sort AFTER the FULL rows of their group so the
        dual-sparse kernel can skip minor-half tiles. None = no mode split.

    Slots are identical to the one-hot-cumsum path (``cumsum_dispatch``) bit
    for bit: stability of the sort preserves arrival order within each
    (group, mode) bucket, so ranks coincide with running counts.
    """
    group = group.reshape(-1)
    N = group.shape[0]
    G = n_groups
    if keep is None:
        keep = jnp.ones((N,), bool)
    else:
        keep = keep.reshape(-1)
    if major_only is None:
        major_only = jnp.zeros((N,), bool)
    else:
        major_only = major_only.reshape(-1) & keep

    # composite key: 2 buckets per group (FULL=0 / MAJOR-only=1), dropped
    # pairs past everything. Stable argsort => arrival order within buckets.
    bucket = jnp.where(keep, group * 2 + major_only.astype(group.dtype),
                       2 * G)
    perm = jnp.argsort(bucket, stable=True)

    counts2 = group_histogram(bucket, 2 * G)                     # (2G,)
    counts_full = counts2[0::2]
    counts_major = counts2[1::2]
    group_counts = counts_full + counts_major
    group_offsets = jnp.cumsum(group_counts) - group_counts      # exclusive

    # rank of each flat pair in sorted order -> slot within its group
    inv = jnp.zeros((N,), jnp.int32).at[perm].set(
        jnp.arange(N, dtype=jnp.int32))
    g_clip = jnp.clip(group, 0, G - 1)
    slot = inv - group_offsets[g_clip]
    overflow = jnp.sum((keep & (slot >= capacity)).astype(jnp.int32))
    slot = jnp.where(keep, jnp.minimum(slot, capacity), capacity)
    return DispatchPlan(perm=perm, group_offsets=group_offsets,
                        counts_full=counts_full, counts_major=counts_major,
                        group=g_clip, slot=slot, overflow=overflow)


def gather_rows(values, plan: DispatchPlan, capacity: int, *,
                index_div: int = 1, fill=0):
    """Materialize the (G, capacity, ...) buffers by GATHERING through the
    plan — no ``jnp.repeat`` of the token block, no scatter.

    values: (M, ...) source rows; flat pair ``i`` reads row
    ``i // index_div`` (pass ``index_div=K`` to read token ``i // K`` for a
    (T, K)-shaped pair list directly from the (T, d) token array).
    Rows beyond a group's kept count are ``fill``.
    """
    N = plan.perm.shape[0]
    G = plan.group_offsets.shape[0]
    pos = plan.group_offsets[:, None] + jnp.arange(capacity)[None, :]
    valid = jnp.arange(capacity)[None, :] < \
        jnp.minimum(plan.counts, capacity)[:, None]              # (G, C)
    src = plan.perm[jnp.clip(pos, 0, N - 1)]                     # (G, C)
    out = values[src // index_div if index_div > 1 else src]
    mask = valid.reshape(G, capacity, *((1,) * (out.ndim - 2)))
    return jnp.where(mask, out, jnp.asarray(fill, out.dtype))


def unpermute(out_buf, plan: DispatchPlan):
    """Read each flat pair's output row back from the (G, C, ...) buffer.
    Dropped/overflowed pairs (slot == capacity) read a zero pad row."""
    padded = jnp.pad(out_buf, ((0, 0), (0, 1)) +
                     ((0, 0),) * (out_buf.ndim - 2))
    return padded[plan.group, plan.slot]


def sorted_pair_arrays(plan: DispatchPlan, weights, *, index_div: int = 1,
                       pad: int = 0):
    """(tok_sorted, weight_sorted) for the fused Pallas MoE pipeline
    (``kernels.dualsparse_ffn.fused_moe_pipeline_pallas``).

    tok_sorted[i] is the source row (flat pair id // ``index_div``) of the
    i-th SORTED pair position; weight_sorted[i] its combine weight (pass
    ``combine * keep`` so dropped pairs carry weight 0). Both O(N) — the
    only per-pair state the fused kernel needs, replacing the
    (G, capacity, d) gathered buffer entirely. ``pad`` appends that many
    (row 0, weight 0) entries so the kernel's final row-block slice stays
    in range (pass its ``block_c``)."""
    src = plan.perm // index_div if index_div > 1 else plan.perm
    w = weights.reshape(-1)[plan.perm]
    if pad:
        src = jnp.pad(src, (0, pad))
        w = jnp.pad(w, (0, pad))
    return src.astype(jnp.int32), w


def prefer_cumsum_dispatch(n_pairs: int, n_groups: int,
                           backend: Optional[str] = None) -> bool:
    """Per-shape dispatch heuristic (ROADMAP): the sort substrate wins
    almost everywhere, but on CPU the dense one-hot cumsum is still faster
    for FEW groups at LARGE pair counts — O(N*G) with G<=8 is one cheap
    vectorized pass, while a stable argsort of ~1e4+ keys pays its
    O(N log N) in scalar compares (BENCH_dispatch.json: T=1024..4096/E=8
    runs 0.68-0.86x). Both build bit-identical plans, so the choice is pure
    performance. TPU/GPU always sort (the dense one-hot is an (N, G)
    HBM-traffic bomb there)."""
    if backend is None:
        backend = jax.default_backend()
    return backend == "cpu" and n_groups <= 8 and n_pairs >= 8192


def prefer_fused_pipeline(n_tokens: int, n_groups: int, *,
                          use_kernel: bool = False,
                          backend: Optional[str] = None) -> bool:
    """Per-shape fused-vs-buffer heuristic (mirrors
    ``prefer_cumsum_dispatch``): should the MoE forward run the streamed
    fused dispatch->FFN->combine Pallas pipeline instead of the
    gather->grouped-FFN->unpermute buffer path?

    On TPU/GPU the streamed kernel is the default at EVERY token count:
    its VMEM working set is independent of T (pair maps in SMEM, x/out in
    HBM with double-buffered DMA), it never materializes the
    (E, capacity, d) buffer, and the bench trajectory
    (BENCH_moe_pipeline.json) shows it at or above buffer throughput from
    decode (T=64) through prefill (T=8192). On CPU the kernels run in
    interpret mode, where the fused kernel still beats the interpreted
    buffer-path Pallas FFN (same trajectory) but loses to the pure-XLA
    einsum the non-kernel policies use — so fused follows ``use_kernel``
    there. All paths agree to fp tolerance; the choice is performance
    only."""
    if backend is None:
        backend = jax.default_backend()
    del n_tokens, n_groups          # today's rule is shape-independent;
    #                                 the signature keeps per-shape tuning
    #                                 open without call-site churn
    if backend != "cpu":
        return True
    return use_kernel


def dispatch_plan(group, keep=None, *, n_groups: int, capacity: int,
                  major_only=None, backend: Optional[str] = None
                  ) -> DispatchPlan:
    """Shape-dispatched planner: ``sort_dispatch`` or ``cumsum_dispatch``
    by ``prefer_cumsum_dispatch`` — bit-identical output either way."""
    n_pairs = int(np.prod(group.shape))
    fn = cumsum_dispatch if prefer_cumsum_dispatch(n_pairs, n_groups,
                                                   backend) else sort_dispatch
    return fn(group, keep, n_groups=n_groups, capacity=capacity,
              major_only=major_only)


# ---------------------------------------------------------------------------
# Dense one-hot cumsum reference (the pre-sort implementation, kept as the
# oracle for equivalence tests and the bench_dispatch baseline)
# ---------------------------------------------------------------------------

def cumsum_dispatch(group, keep=None, *, n_groups: int, capacity: int,
                    major_only=None) -> DispatchPlan:
    """O(N·G) reference: dense one-hot + cumsum running counts. Mode
    ordering is two-phase (FULL ranks first, MAJOR-only ranks offset by the
    group's FULL count) so slots match ``sort_dispatch`` exactly."""
    group = group.reshape(-1)
    N = group.shape[0]
    G = n_groups
    if keep is None:
        keep = jnp.ones((N,), bool)
    else:
        keep = keep.reshape(-1)
    if major_only is None:
        major_only = jnp.zeros((N,), bool)
    else:
        major_only = major_only.reshape(-1) & keep
    g_clip = jnp.clip(group, 0, G - 1)

    def running(mask):
        onehot = jax.nn.one_hot(g_clip, G, dtype=jnp.int32)
        onehot = onehot * mask[:, None].astype(jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot                # (N, G)
        return (jnp.take_along_axis(pos, g_clip[:, None], axis=1)[:, 0],
                onehot.sum(axis=0))

    full_mask = keep & ~major_only
    pos_f, counts_full = running(full_mask)
    pos_m, counts_major = running(major_only)
    true_slot = jnp.where(major_only, counts_full[g_clip] + pos_m, pos_f)
    overflow = jnp.sum((keep & (true_slot >= capacity)).astype(jnp.int32))
    slot = jnp.where(keep, jnp.minimum(true_slot, capacity), capacity)

    group_counts = counts_full + counts_major
    group_offsets = jnp.cumsum(group_counts) - group_counts
    # perm via scatter of each kept pair into its sorted position (the
    # UNclamped rank — overflowed pairs still occupy a unique position);
    # dropped pairs fill the tail in arrival order
    drop = (~keep).astype(jnp.int32)
    rank_drop = jnp.cumsum(drop) - drop
    sorted_pos = jnp.where(keep, group_offsets[g_clip] + true_slot,
                           jnp.sum(group_counts) + rank_drop)
    perm = jnp.zeros((N,), jnp.int32).at[sorted_pos].set(
        jnp.arange(N, dtype=jnp.int32))
    return DispatchPlan(perm=perm, group_offsets=group_offsets,
                        counts_full=counts_full, counts_major=counts_major,
                        group=g_clip, slot=slot, overflow=overflow)


def scatter_rows(values, plan: DispatchPlan, capacity: int, *,
                 index_div: int = 1, fill=0):
    """Reference buffer construction of the pre-sort path: repeat + scatter
    into a (G, capacity+1, ...) buffer (row ``capacity`` is the discard
    row). Used by tests/benchmarks to pin gather_rows equivalence."""
    N = plan.group.shape[0]
    src = jnp.arange(N) // index_div if index_div > 1 else jnp.arange(N)
    rows = values[src]                                           # repeat
    G = plan.group_offsets.shape[0]
    buf = jnp.full((G, capacity + 1) + values.shape[1:], fill, values.dtype)
    buf = buf.at[plan.group, plan.slot].set(rows)
    return buf[:, :capacity]


# ---------------------------------------------------------------------------
# Mode helpers: original-expert ("fused") grouping for the dual-sparse kernel
# ---------------------------------------------------------------------------

def major_only_flags(keep, p: int):
    """Per-sub-pair MAJOR-only flags from an expanded (T, K*P) keep mask.

    Sub-expert 0 of an original pair is the MAJOR half; a pair is MAJOR-only
    when its major half is kept but every minor half is dropped (2T mode 1).
    Requires mode-monotone keeps (a kept minor implies a kept major), which
    every registered drop policy satisfies. Returns (T, K*P) bool with the
    flag on the major sub-pair only."""
    if p <= 1:
        return jnp.zeros_like(keep, dtype=bool)
    T, Kp = keep.shape
    k3 = keep.reshape(T, Kp // p, p)
    flag3 = jnp.zeros_like(k3)
    flag3 = flag3.at[..., 0].set(k3[..., 0] & ~k3[..., 1:].any(-1))
    return flag3.reshape(T, Kp)


class FusedGroups(NamedTuple):
    """Original-expert-granularity view of an expanded sub-pair list."""
    group: jax.Array       # (T, K) original expert per pair
    keep: jax.Array        # (T, K) any half kept
    major_only: jax.Array  # (T, K) only the major half kept
    combine: jax.Array     # (T, K) combine weight (shared by the halves)


def fuse_sub_pairs(pairs, p: int) -> FusedGroups:
    """Collapse a (T, K*P) sub-expert pair list to (T, K) ORIGINAL-expert
    groups for the fused dual-sparse kernel: one dispatched row per original
    pair (halving traffic at P=2), FULL vs MAJOR-only decided by which
    halves the policy kept. Exact under partial transformation (Eq. 13):
    the combine weight is shared and sub-expert outputs add, so
    c·(f_major + f_minor) == c·f_full and c·f_major is the mode-1 row the
    kernel computes by skipping minor-half tiles."""
    T, Kp = pairs.idx.shape
    K = Kp // p
    idx3 = pairs.idx.reshape(T, K, p)
    keep3 = pairs.keep.reshape(T, K, p)
    comb3 = pairs.combine.reshape(T, K, p)
    return FusedGroups(
        group=idx3[..., 0] // p,
        keep=keep3.any(-1),
        major_only=keep3[..., 0] & ~keep3[..., 1:].any(-1),
        combine=comb3[..., 0],
    )
