"""MoE gating (paper §2.1.1, Eqs. 1-3).

Distinguishes the *combine weight* (what multiplies each expert output —
renormalized top-k for Qwen3/Mixtral-style routers, raw softmax score for
DeepSeek-style) from the *normalized gating score* used by the DualSparse
drop decision (paper §4.1 always normalizes over the selected top-k).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Routing(NamedTuple):
    """Top-k routing decision for a flat batch of T tokens."""
    idx: jax.Array          # (T, K) int32 — selected expert ids
    combine: jax.Array      # (T, K) f32 — weight applied to expert outputs
    norm_score: jax.Array   # (T, K) f32 — normalized score for drop decisions
    probs: jax.Array        # (T, E) f32 — full softmax (for aux losses/tests)


def gate_logits(x, wg):
    """x: (T, d), wg: (d, E) -> (T, E) f32 logits (Eq. 5)."""
    return (x.astype(jnp.float32) @ wg.astype(jnp.float32))


def top_k_routing(logits, k: int, renorm: bool) -> Routing:
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E) Eq. 6
    vals, idx = jax.lax.top_k(probs, k)                       # (T, K)
    denom = jnp.sum(vals, axis=-1, keepdims=True)
    norm_score = vals / jnp.maximum(denom, 1e-20)             # §4.1 normalize
    combine = norm_score if renorm else vals
    return Routing(idx=idx, combine=combine, norm_score=norm_score, probs=probs)


def route(x, wg, k: int, renorm: bool) -> Routing:
    return top_k_routing(gate_logits(x, wg), k, renorm)


def load_balance_aux_loss(probs, idx, n_experts: int):
    """Switch-style auxiliary load-balance loss for training runs."""
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = expert_histogram(idx, n_experts).astype(probs.dtype) / idx.shape[0]
    return n_experts * jnp.sum(me * ce)


def expert_histogram(idx, n_experts: int, keep=None):
    """Token count per expert; ``keep`` optionally masks dropped pairs.
    O(N) segment histogram — no dense (T, K, E) one-hot intermediate."""
    from .dispatch import group_histogram
    return group_histogram(idx, n_experts, mask=keep)
