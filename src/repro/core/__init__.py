"""DualSparse-MoE core: the paper's contribution as composable JAX modules.

- gating       : top-k routing (Eqs. 1-3)
- partition    : complete / partial expert transformations (§3.1-3.2)
- reconstruct  : neuron-importance profiling + major/minor reconstruction (§4.2b)
- drop         : 1T / 2T token-expert computation dropping (§4.1-4.2)
- load_aware   : load-aware thresholding for EP (§4.3)
- moe          : MoE layer (reference + capacity dispatch)
- setp         : Soft Expert-Tensor Parallelism via shard_map (§3.3)
- policy       : first-class SparsityPolicy objects tying it all together
"""
from . import gating, partition, reconstruct, drop, load_aware, moe  # noqa: F401
from . import policy  # noqa: F401
from .policy import (LoadAwareTwoT, NoDrop, OneTDrop,  # noqa: F401
                     PerLayerCalibrated2T, SparsityPolicy, TwoTDrop,
                     make_policy)
