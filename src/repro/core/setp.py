"""Soft Expert-Tensor Parallelism (paper §3.3) and the ETP baseline.

S-ETP = partial transformation + plain EP. Each original expert is split into
P sub-experts; sub-experts are placed *strided* across the EP axis
(sub-expert ``id`` lives on device ``id % D``), so the P halves of one expert
sit on different devices — the tensor-parallel memory/compute split — while
the communication pattern stays a single AlltoAll each way (Fig. 5b).

The ETP baseline (Fig. 5a) shards whole experts over an ``ep`` sub-axis and
each expert's d_ff over a ``tp`` sub-axis, paying AlltoAll+AllGather on
dispatch and ReduceScatter+AlltoAll on return.

Both are shard_map bodies in plain JAX (jax.lax collectives). Load-aware
thresholding (§4.3) costs one psum of a (D,) histogram.

All seating (device-level and local-expert-level) runs on the shared
sort-based dispatch substrate (``core.dispatch``): stable argsort keys,
segment-histogram counts, gather-built buffers — no dense one-hot cumsum,
no ``jnp.repeat`` of the token block. Local buffers are mode-ordered
(FULL rows first, MAJOR-only rows second; the flag rides in the low bit of
the AlltoAll id payload) so ``counts_full``/``counts_major`` feed the
dual-sparse kernel, and capacity-overflow drops are counted and psum'd out
of the body (``setp_moe_forward(return_overflow=True)``).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6: top-level jax.shard_map (replication check kw: check_vma)
    from jax import shard_map as _shard_map_impl
    _REP_CHECK_KW = "check_vma"
except ImportError:  # older jax: experimental namespace (kw: check_rep)
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _REP_CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """Version-tolerant shard_map: same call-sites work on old and new JAX."""
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs,
                           **{_REP_CHECK_KW: check_vma})

from . import dispatch as dispatch_mod
from . import drop as drop_mod
from . import gating, moe as moe_mod


# ---------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------

def to_strided_order(w, n_dev: int):
    """Reorder the leading (sub-)expert axis from id-order to placement order
    so that a contiguous shard_map shard holds device d's sub-experts.

    id = loc * D + d  ->  placed[d * L + loc] = w[id]."""
    Ep = w.shape[0]
    L = Ep // n_dev
    return w.reshape(L, n_dev, *w.shape[1:]).swapaxes(0, 1).reshape(w.shape)


def place_params_strided(params: Dict, n_dev: int) -> Dict:
    out = dict(params)
    for k in ("w1", "w3", "w2"):
        out[k] = to_strided_order(params[k], n_dev)
    return out


# ---------------------------------------------------------------------------
# S-ETP shard_map body
# ---------------------------------------------------------------------------

def _ceil_mult(x: float, m: int = 8) -> int:
    return max(m, int(np.ceil(x / m) * m)) if m > 1 else max(1, int(np.ceil(x)))


def _setp_body(wg, w1, w3, w2, x_loc, *, cfg, n_dev: int, axis: str,
               token_axes: tuple, policy, thresholds=None,
               cap_factor: float, local_cap_factor: float,
               cap_multiple: int = 8, wire_dtype=jnp.bfloat16,
               tokens_on_axis: bool = True, collect_stats: bool = False):
    """Per-device S-ETP MoE. x_loc: (B_l, S_l, d). Experts already
    partial-transformed (E*P sub-experts when ``policy.partition_p > 1``)
    and strided-placed; this device holds w1/w3/w2 slices of L = E*P/D
    sub-experts. The ``policy`` decides the keep mask over expanded
    sub-expert pairs; a load-aware policy additionally costs one psum of
    the (D,) pre-drop device histogram. ``thresholds``: optional per-layer
    calibrated (2,) pair threaded through the shard_map (replicated)."""
    p_factor = policy.partition_p
    use_kernel = policy.use_kernel
    Bl, Sl, d = x_loc.shape
    xt = x_loc.reshape(-1, d)
    T = xt.shape[0]
    L = w1.shape[0]                              # local sub-experts
    # whole-body compute dtype == wire dtype: keeps the AlltoAll in bf16
    # (a convert adjacent to the collective gets hoisted across it by the
    # algebraic simplifier, silently doubling interconnect bytes)
    w1 = w1.astype(wire_dtype)
    w3 = w3.astype(wire_dtype)
    w2 = w2.astype(wire_dtype)

    r = gating.route(xt, wg, cfg.top_k, cfg.router_norm_topk)
    K = cfg.top_k

    # --- partial transformation of the routing (Eq. 12) + 2T keep mask ---
    sub = jnp.arange(p_factor, dtype=r.idx.dtype)
    sub_idx = (r.idx[:, :, None] * p_factor + sub).reshape(T, K * p_factor)
    combine = jnp.repeat(r.combine[:, :, None], p_factor, axis=2)
    combine = combine.reshape(T, K * p_factor)
    dev_of = sub_idx % n_dev
    loc_of = sub_idx // n_dev
    score = jnp.repeat(r.norm_score[:, :, None], p_factor, axis=2)
    score = score.reshape(T, K * p_factor)
    is_major = (sub_idx % p_factor) == 0 if p_factor > 1 else \
        jnp.ones_like(sub_idx, dtype=bool)

    loads = None
    if policy.needs_loads:
        # pre-drop load histogram per EP device — one psum (O(N) segment
        # histogram; no dense one-hot). Sum over the expert axis ONLY when
        # tokens are actually sharded over it (prefill/train); on decode
        # steps (S == 1) the token block is REPLICATED over the expert axis,
        # and psum'ing the identical per-device histograms would multiply
        # every load by n_dev — skewing load-aware thresholds toward
        # uniform-looking (capped) ratios.
        loads = dispatch_mod.group_histogram(dev_of, n_dev,
                                             dtype=jnp.float32)
        for ax in token_axes + ((axis,) if tokens_on_axis else ()):
            loads = jax.lax.psum(loads, ax)
    keep = policy.sub_pair_keep(score, is_major, sub_idx, cfg, n_dev=n_dev,
                                loads=loads, thresholds=thresholds)

    stats = None
    if collect_stats:
        # routing-time metrics (pre-dispatch): kept-pair histogram over the
        # GLOBAL sub-expert ids plus mode-attributed keep/drop counts. Like
        # ``loads`` above, psum over the expert axis only when tokens are
        # sharded over it — on decode the token block is replicated there
        # and summing identical copies would multiply every count by n_dev.
        hist = dispatch_mod.group_histogram(sub_idx, L * n_dev, mask=keep)
        kf, km, dr = drop_mod.sub_pair_outcome_counts(keep, p_factor)
        for ax in token_axes + ((axis,) if tokens_on_axis else ()):
            hist, kf, km, dr = jax.lax.psum((hist, kf, km, dr), ax)
        stats = {"expert_load": hist, "kept_full": kf, "kept_major": km,
                 "dropped_pairs": dr}

    Kp = K * p_factor
    cap = _ceil_mult(cap_factor * T * Kp / n_dev, cap_multiple)

    # --- dispatch: sort-based seating per destination device ---
    # MAJOR-only flags ride to the owning device (low bit of the id
    # payload) so its local buffers can be mode-ordered for the kernel.
    mflag = dispatch_mod.major_only_flags(keep, p_factor)
    plan_dev = dispatch_mod.sort_dispatch(dev_of, keep,
                                          n_groups=n_dev, capacity=cap)
    # bf16 on the wire: halves AlltoAll traffic; experts compute from bf16
    # activations (standard practice) while the combine stays in x dtype.
    send_x = dispatch_mod.gather_rows(xt.astype(wire_dtype), plan_dev, cap,
                                      index_div=Kp)
    payload = loc_of * 2 + mflag.astype(loc_of.dtype)
    send_e = dispatch_mod.gather_rows(payload.reshape(-1), plan_dev, cap,
                                      fill=-1)

    # --- the S-ETP collective: ONE AlltoAll each way (Fig. 5b) ---
    recv_x = jax.lax.all_to_all(send_x, axis, 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, axis, 0, 0, tiled=False)

    # --- local grouped expert FFN (mode-ordered buffers) ---
    rx = recv_x.reshape(n_dev * cap, d)
    re2 = recv_e.reshape(-1)
    valid = re2 >= 0
    loc = jnp.where(valid, re2 // 2, 0)
    mfl = valid & ((re2 & 1) == 1)
    c2 = _ceil_mult(local_cap_factor * n_dev * cap / L, cap_multiple)
    plan_loc = dispatch_mod.sort_dispatch(loc, valid, n_groups=L,
                                          capacity=c2, major_only=mfl)
    fused = getattr(policy, "fused_pipeline", None)
    if fused is None:
        # auto: same per-shape/backend heuristic as the dispatch path
        fused = dispatch_mod.prefer_fused_pipeline(rx.shape[0], L,
                                                   use_kernel=use_kernel)
    if fused:
        # single fused Pallas pipeline: the kernel gathers received rows
        # straight through plan_loc.perm, runs the grouped SwiGLU, and
        # scatters back per received row — no (L, c2, d) buffer, no
        # unpermute. Validity rides as the combine weight (1 kept / 0 pad),
        # replacing the ``* valid`` mask of the buffer path.
        from ..kernels import ops as kops
        cf, cm = plan_loc.kernel_counts(c2)
        bc = min(128, c2)
        tok_s, w_s = dispatch_mod.sorted_pair_arrays(
            plan_loc, valid.astype(jnp.float32), pad=bc)
        out_tok = kops.fused_moe_pipeline(
            rx, w1, w3, w2, plan_loc.group_offsets, cf, cm, tok_s, w_s,
            capacity=c2, n_minor_start=w1.shape[-1],
            block_c=bc).astype(wire_dtype)
    else:
        buf = dispatch_mod.gather_rows(rx, plan_loc, c2)
        if use_kernel:
            from ..kernels import ops as kops
            cf, cm = plan_loc.kernel_counts(c2)
            # each local group IS one sub-expert (the halves of an original
            # expert live on different devices — that is the S-ETP split),
            # so no minor-half neuron region exists locally: counts_major
            # tracks the mode ordering and pads tile-skip row validity only.
            out_buf = kops.grouped_swiglu(buf, w1, w3, w2, counts_full=cf,
                                          counts_major=cm,
                                          n_minor_start=w1.shape[-1])
        else:
            out_buf = moe_mod.expert_ffn(w1, w3, w2, buf)
        out_tok = dispatch_mod.unpermute(out_buf, plan_loc).astype(wire_dtype)
        out_tok = out_tok * valid[:, None].astype(out_tok.dtype)

    # --- return AlltoAll + combine on the source device ---
    back = jax.lax.all_to_all(out_tok.reshape(n_dev, cap, d), axis, 0, 0)
    back = jnp.pad(back, ((0, 0), (0, 1), (0, 0)))
    out_pair = back[plan_dev.group, plan_dev.slot]               # (T*Kp, d)
    flat_keep = keep.reshape(-1)
    w = (combine.reshape(-1) * flat_keep.astype(combine.dtype))
    y = (out_pair * w[:, None].astype(out_pair.dtype)).reshape(T, Kp, d).sum(1)
    # kept pairs silently discarded by capacity overflow, globally summed:
    # device-level seating + local-expert-level seating on this shard
    overflow = plan_dev.overflow + plan_loc.overflow
    for ax in token_axes + (axis,):
        overflow = jax.lax.psum(overflow, ax)
    y = y.reshape(Bl, Sl, d).astype(x_loc.dtype)
    if collect_stats:
        stats["overflow_pairs"] = overflow
        return y, stats
    return y, overflow


def _spec_uses_axis(spec, axis: str) -> bool:
    """Whether a PartitionSpec shards any dimension over ``axis`` — i.e.
    whether the per-shard token block is a distinct slice along it (vs
    replicated, as on decode steps)."""
    for entry in spec:
        if entry == axis:
            return True
        if isinstance(entry, (tuple, list)) and axis in entry:
            return True
    return False


def setp_moe_forward(params: Dict, x, cfg, mesh: Mesh, *,
                     expert_axis: str = "model", policy=None,
                     cap_factor: float = 1.15, local_cap_factor: float = 1.25,
                     cap_multiple: int = 8, wire_dtype=jnp.bfloat16,
                     x_spec: Optional[P] = None,
                     return_overflow: bool = False,
                     return_stats: bool = False):
    """S-ETP MoE layer under a ``SparsityPolicy`` (default ``NoDrop``).
    params' experts must already be prepared by the SAME policy
    (``policy.prepare(...)``: partial transformation + reconstruction for
    drop policies) AND strided-placed via
    ``place_params_strided(params, mesh.shape[expert_axis])``.

    x: (B, S, d) — batch sharded over (pod, data), seq sharded over
    ``expert_axis`` so the AlltoAll happens within each data-parallel group.

    ``return_overflow``: also return the GLOBAL (psum'd, replicated) count
    of kept token/sub-expert pairs silently discarded by device-level or
    local-expert-level capacity overflow — the unsanctioned accuracy loss a
    deployment must watch, previously invisible on this path.

    ``return_stats``: instead return ``(y, stats)`` where stats is the
    ``repro.obs`` per-layer dict (kept-pair ``expert_load`` histogram over
    global sub-expert ids plus kept_full/kept_major/dropped_pairs/
    overflow_pairs int32 scalars), all globally psum'd and replicated.
    Supersedes ``return_overflow`` when both are set.
    """
    if policy is None:
        from .policy import NoDrop
        policy = NoDrop()
    n_dev = mesh.shape[expert_axis]
    token_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if x_spec is None:
        from ..distributed.sharding import batch_spec
        # shard seq over the expert axis when divisible (prefill/train);
        # decode steps (S == 1) keep seq replicated.
        seq_ax = expert_axis if x.shape[1] % n_dev == 0 else None
        x_spec = batch_spec(x.shape[0], mesh, extra=(seq_ax, None))
    body = functools.partial(
        _setp_body, cfg=cfg, n_dev=n_dev, axis=expert_axis,
        token_axes=token_axes, policy=policy,
        cap_factor=cap_factor, local_cap_factor=local_cap_factor,
        cap_multiple=cap_multiple, wire_dtype=wire_dtype,
        tokens_on_axis=_spec_uses_axis(x_spec, expert_axis),
        collect_stats=return_stats)

    # per-layer calibrated thresholds ride through the shard_map replicated
    has_th = "thresholds" in params
    args = [params["wg"], params["w1"], params["w3"], params["w2"]]
    in_specs = [P(), P(expert_axis), P(expert_axis), P(expert_axis)]
    if has_th:
        args.append(params["thresholds"])
        in_specs.append(P())
    args.append(x)
    in_specs.append(x_spec)

    def fn(wg, w1, w3, w2, *rest):
        if has_th:
            th, xx = rest
        else:
            th, (xx,) = None, rest
        return body(wg, w1, w3, w2, xx, thresholds=th)

    if return_stats:
        aux_spec = {"expert_load": P(), "kept_full": P(), "kept_major": P(),
                    "dropped_pairs": P(), "overflow_pairs": P()}
    else:
        aux_spec = P()
    y, aux = shard_map(
        fn, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(x_spec, aux_spec), check_vma=False,
    )(*args)
    if "shared" in params:
        s = params["shared"]
        h = jax.nn.silu(x @ s["w1"]) * (x @ s["w3"])
        y = y + h @ s["w2"]
    if return_stats:
        return y, aux
    return (y, aux) if return_overflow else y


# ---------------------------------------------------------------------------
# ETP baseline (Fig. 5a): EP over `ep` axis, TP over `tp` axis
# ---------------------------------------------------------------------------

def _etp_body(wg, w1, w3, w2, x_loc, *, cfg, n_ep: int, n_tp: int,
              cap_factor: float, local_cap_factor: float):
    """w1/w3: (E_loc, d, f/tp); w2: (E_loc, f/tp, d). Tokens sharded over ep
    (and replicated over tp). Pattern: AlltoAll(ep) + AllGather(tp) dispatch,
    partial FFN, ReduceScatter(tp) + AlltoAll(ep) return."""
    Bl, Sl, d = x_loc.shape
    xt = x_loc.reshape(-1, d)
    T = xt.shape[0]
    L = w1.shape[0]
    r = gating.route(xt, wg, cfg.top_k, cfg.router_norm_topk)
    K = cfg.top_k
    dev_of = r.idx // L
    loc_of = r.idx % L
    cap = _ceil_mult(cap_factor * T * K / n_ep)
    plan_dev = dispatch_mod.sort_dispatch(dev_of, n_groups=n_ep,
                                          capacity=cap)
    send_x = dispatch_mod.gather_rows(xt, plan_dev, cap, index_div=K)
    send_e = dispatch_mod.gather_rows(loc_of.reshape(-1), plan_dev, cap,
                                      fill=-1)

    # dispatch: AlltoAll over ep ...
    recv_x = jax.lax.all_to_all(send_x, "ep", 0, 0)
    recv_e = jax.lax.all_to_all(send_e, "ep", 0, 0)
    # ... + AllGather over tp (each tp rank computed routing for its own
    # token shard; expert compute needs the full token set of the ep group)
    recv_x = jax.lax.all_gather(recv_x, "tp", tiled=False)      # (tp, nev, cap, d)
    recv_e = jax.lax.all_gather(recv_e, "tp", tiled=False)
    rx = recv_x.reshape(-1, d)
    re = recv_e.reshape(-1)
    valid = re >= 0
    n_recv = rx.shape[0]
    c2 = _ceil_mult(local_cap_factor * n_recv / L)
    plan_loc = dispatch_mod.sort_dispatch(jnp.where(valid, re, 0), valid,
                                          n_groups=L, capacity=c2)
    buf = dispatch_mod.gather_rows(rx, plan_loc, c2)
    out_buf = moe_mod.expert_ffn(w1, w3, w2, buf)     # partial over f/tp
    out_tok = dispatch_mod.unpermute(out_buf, plan_loc)
    out_tok = out_tok * valid[:, None].astype(rx.dtype)
    out_tok = out_tok.reshape(n_tp, n_ep, cap, d)
    # return: ReduceScatter over tp (sum partial FFN outputs, keep own shard)
    out_own = jax.lax.psum_scatter(out_tok, "tp", scatter_dimension=0,
                                   tiled=False)                  # (nev, cap, d)
    back = jax.lax.all_to_all(out_own, "ep", 0, 0)
    back = jnp.pad(back, ((0, 0), (0, 1), (0, 0)))
    out_pair = back[plan_dev.group, plan_dev.slot]
    w = r.combine.reshape(-1)
    y = (out_pair * w[:, None].astype(out_pair.dtype)).reshape(T, K, d).sum(1)
    return y.reshape(Bl, Sl, d).astype(x_loc.dtype)


def etp_moe_forward(params: Dict, x, cfg, mesh: Mesh, *,
                    ep_axis: str = "ep", tp_axis: str = "tp",
                    cap_factor: float = 1.3, local_cap_factor: float = 2.0):
    """ETP baseline. Expert weights sharded (expert over ep, d_expert over tp);
    tokens sharded over ep, replicated over tp."""
    n_ep, n_tp = mesh.shape[ep_axis], mesh.shape[tp_axis]
    body = functools.partial(_etp_body, cfg=cfg, n_ep=n_ep, n_tp=n_tp,
                             cap_factor=cap_factor,
                             local_cap_factor=local_cap_factor)
    x_spec = P(ep_axis, None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(ep_axis, None, tp_axis), P(ep_axis, None, tp_axis),
                  P(ep_axis, tp_axis, None), x_spec),
        out_specs=x_spec, check_vma=False,
    )(params["wg"], params["w1"], params["w3"], params["w2"], x)
