"""Expert partition: complete and partial transformations (paper §3).

Both transformations are mathematically exact restructurings of a pre-trained
MoE layer; the tests in tests/test_partition.py assert allclose equivalence
(paper Eqs. 11 and 13).

Params layout (see core.moe.make_moe_params):
    wg: (d, E)   w1, w3: (E, d, f)   w2: (E, f, d)
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp


def _partition_expert_weights(w1, w3, w2, p: int):
    """Evenly split each expert's neurons into p contiguous sub-experts.

    (E, d, f) -> (E*p, d, f/p); (E, f, d) -> (E*p, f/p, d).
    Sub-expert e*p + j holds neuron slice [j*f/p, (j+1)*f/p) of expert e.
    """
    E, d, f = w1.shape
    assert f % p == 0, f"d_expert {f} not divisible by partition factor {p}"
    fp = f // p
    w1p = w1.reshape(E, d, p, fp).transpose(0, 2, 1, 3).reshape(E * p, d, fp)
    w3p = w3.reshape(E, d, p, fp).transpose(0, 2, 1, 3).reshape(E * p, d, fp)
    w2p = w2.reshape(E, p, fp, d).reshape(E * p, fp, d)
    return w1p, w3p, w2p


def complete_transform(params: Dict, p: int) -> Dict:
    """Complete transformation (§3.1): the result is a *standard* MoE layer
    with E*p experts and Top-(K*p) selection that computes the identical
    function: gating rows repeated p times (Eq. 7), neurons partitioned,
    down-projection W2 scaled by p (Eq. 11 scaling choice (2))."""
    wg = params["wg"]
    d, E = wg.shape
    wg_p = jnp.repeat(wg, p, axis=1)                        # (d, E*p), Eq. 7
    w1p, w3p, w2p = _partition_expert_weights(
        params["w1"], params["w3"], params["w2"], p)
    out = dict(params)
    out.update({"wg": wg_p, "w1": w1p, "w3": w3p, "w2": w2p * p})
    return out


def partial_transform(params: Dict, p: int) -> Dict:
    """Partial transformation (§3.2): gating network untouched; only expert
    weights are split. Score repetition / index remapping (Eq. 12) happens at
    routing time — see core.drop.expand_pairs_*. No W2 scaling (Eq. 13)."""
    w1p, w3p, w2p = _partition_expert_weights(
        params["w1"], params["w3"], params["w2"], p)
    out = dict(params)
    out.update({"w1": w1p, "w3": w3p, "w2": w2p})
    return out


def invert_partial(params: Dict, p: int) -> Dict:
    """Reverse of partial_transform (the paper notes partial transformation
    is reversible since the gating network is preserved)."""
    w1, w3, w2 = params["w1"], params["w3"], params["w2"]
    Ep, d, fp = w1.shape
    E = Ep // p
    w1o = w1.reshape(E, p, d, fp).transpose(0, 2, 1, 3).reshape(E, d, p * fp)
    w3o = w3.reshape(E, p, d, fp).transpose(0, 2, 1, 3).reshape(E, d, p * fp)
    w2o = w2.reshape(E, p, fp, d).reshape(E, p * fp, d)
    out = dict(params)
    out.update({"w1": w1o, "w3": w3o, "w2": w2o})
    return out


def dense_ffn_partition(w1, w3, w2, p: int):
    """Beyond-paper: exact partition of a *dense* SwiGLU FFN into p uniform
    sub-FFNs (gate == 1 each), enabling S-ETP-style all-to-all sharding for
    the dense/hybrid assigned architectures. sum_j f_j(x) == f(x)."""
    w1 = w1[None]
    w3 = w3[None]
    w2 = w2[None]
    return _partition_expert_weights(w1, w3, w2, p)
