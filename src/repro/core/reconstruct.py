"""Static expert reconstruction (paper §4.2(b)).

Neuron-importance profiling on calibration samples (four metrics,
Eqs. 14-17), then a per-expert neuron permutation that sorts neurons by
importance so that after partial transformation with P=2 sub-expert
``2e`` holds the MAJOR (important) half and ``2e+1`` the MINOR half.

Reordering neurons of a SwiGLU expert is an exact transformation:
permuting columns of W1/W3 together with rows of W2 leaves f(x) unchanged.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import gating

IMPORTANCE_METHODS = ("gate", "abs_gate", "gate_up", "abs_gate_up")


def neuron_importance(params: Dict, x, cfg, method: str = "abs_gate",
                      routed_only: bool = True):
    """Accumulated neuron importance per (expert, neuron).

    x: (T, d) calibration activations entering the MoE layer.
    Eq. 14 gate: Σ Swish(x·W1)        Eq. 15 abs_gate: Σ |Swish(x·W1)|
    Eq. 16 gate_up: Σ Swish(x·W1)⊙(x·W3)   Eq. 17 abs_gate_up: Σ |...|

    ``routed_only`` accumulates only over tokens actually routed to the
    expert (matching the paper's inference-time profiling).
    """
    if method not in IMPORTANCE_METHODS:
        raise ValueError(f"unknown importance method {method}")
    E = params["w1"].shape[0]
    g = jax.nn.silu(jnp.einsum("td,edf->etf", x, params["w1"]))   # (E,T,f)
    if method in ("gate_up", "abs_gate_up"):
        up = jnp.einsum("td,edf->etf", x, params["w3"])
        g = g * up
    if method.startswith("abs"):
        g = jnp.abs(g)
    if routed_only:
        r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
        # (T, E) routed-membership by scatter-add — no (T, K, E) one-hot
        T = r.idx.shape[0]
        sel = jnp.zeros((T, E), g.dtype).at[
            jnp.arange(T)[:, None], r.idx].add(1.0).T              # (E,T)
        g = g * sel[:, :, None]
    return g.sum(axis=1)                                           # (E, f)


def reorder_neurons(params: Dict, importance) -> Dict:
    """Permute each expert's neurons so importance is descending (exact)."""
    order = jnp.argsort(-importance, axis=-1)                      # (E, f)
    w1 = jnp.take_along_axis(params["w1"], order[:, None, :], axis=2)
    w3 = jnp.take_along_axis(params["w3"], order[:, None, :], axis=2)
    w2 = jnp.take_along_axis(params["w2"], order[:, :, None], axis=1)
    out = dict(params)
    out.update({"w1": w1, "w3": w3, "w2": w2})
    return out


def partition_and_reconstruct(params: Dict, x, cfg, p: int = 2,
                              method: str = "abs_gate") -> Dict:
    """The paper's unified process (§4.2(b)): profile all neurons of each
    original expert, reorder by importance, then partial-transform so the
    major sub-expert is ``e*p`` and minor sub-experts are ``e*p+1..``."""
    from . import partition as part
    imp = neuron_importance(params, x, cfg, method)
    reordered = reorder_neurons(params, imp)
    return part.partial_transform(reordered, p)
