"""Config registry: one module per assigned architecture plus the paper's
own evaluation models (as synthetic-weight layouts)."""
from __future__ import annotations

from .base import ModelConfig, DualSparseConfig, InputShape, INPUT_SHAPES

from . import zamba2_7b
from . import granite_20b
from . import starcoder2_3b
from . import qwen3_moe_30b_a3b
from . import qwen2_vl_7b
from . import mamba2_370m
from . import dbrx_132b
from . import whisper_large_v3
from . import qwen2_7b
from . import minicpm3_4b
from . import paper_models

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch id {cfg.arch_id}")
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


for _mod in (
    zamba2_7b, granite_20b, starcoder2_3b, qwen3_moe_30b_a3b, qwen2_vl_7b,
    mamba2_370m, dbrx_132b, whisper_large_v3, qwen2_7b, minicpm3_4b,
    paper_models,
):
    for _cfg in _mod.CONFIGS:
        register(_cfg)

ASSIGNED_ARCHS = [
    "zamba2-7b", "granite-20b", "starcoder2-3b", "qwen3-moe-30b-a3b",
    "qwen2-vl-7b", "mamba2-370m", "dbrx-132b", "whisper-large-v3",
    "qwen2-7b", "minicpm3-4b",
]


def get_config(arch_id: str) -> ModelConfig:
    try:
        return _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")


def list_archs() -> list[str]:
    return sorted(_REGISTRY)
