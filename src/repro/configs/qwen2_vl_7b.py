"""Qwen2-VL-7B [arXiv:2409.12191] — VLM; language backbone with M-RoPE
(multimodal rotary, sections over (t,h,w)). Vision encoder is a STUB: the
frontend provides precomputed patch embeddings merged into the sequence.
28L, d_model 3584, 28 heads (kv=4), d_ff 18944, vocab 152064."""
from .base import ModelConfig

CONFIGS = [
    ModelConfig(
        arch_id="qwen2-vl-7b",
        family="vlm",
        source="arXiv:2409.12191",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        attn_kind="gqa",
        rope_theta=1e6,
        qkv_bias=True,
        mrope_sections=(16, 24, 24),   # half of head_dim 128
        frontend="vision",
        n_frontend_tokens=1024,        # stub: patch embeddings prepended
        sliding_window=8192,
    )
]
