"""Granite-20B-Code [arXiv:2405.04324] — llama-arch dense code model with
MQA (1 kv head). 52L, d_model 6144, 48 heads, d_ff 24576, vocab 49152."""
from .base import ModelConfig

CONFIGS = [
    ModelConfig(
        arch_id="granite-20b",
        family="dense",
        source="arXiv:2405.04324",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab_size=49152,
        attn_kind="gqa",
        mlp_kind="gelu",
        rope_theta=1e4,
        sliding_window=8192,
    )
]
