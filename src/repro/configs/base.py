"""Model configuration dataclasses for all supported architectures.

Every assigned architecture gets one module in this package instantiating a
``ModelConfig`` with the exact dimensions from its source paper / model card.
``reduced()`` produces the CPU-smoke variant (≤2 layers, d_model ≤ 512,
≤4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Tuple


@dataclass(frozen=True)
class DualSparseConfig:
    """DualSparse-MoE inference-system knobs (paper §4)."""
    enabled: bool = False
    partition_p: int = 2            # partial-transformation factor (P)
    t_drop: float = 0.08            # 1T-Drop threshold on normalized scores
    t_major: float = 0.07           # 2T: below -> drop entirely
    t_minor: float = 0.09           # 2T: above -> full expert; between -> major half
    importance: str = "abs_gate"    # gate | abs_gate | gate_up | abs_gate_up
    load_aware: bool = False        # §4.3 load-aware thresholding in EP
    t_max: float = 0.12             # max threshold for overloaded devices


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    source: str                     # citation
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # --- attention ---
    attn_kind: str = "gqa"          # gqa | mla | none
    rope_theta: float = 1e4
    qkv_bias: bool = False
    sliding_window: int = 0         # 0 = full attention; >0 used by swa variant
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE sections (half-dim)

    # --- MLA (minicpm3 / deepseek-style) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MLP ---
    mlp_kind: str = "swiglu"        # swiglu (3 mats) | gelu (2 mats)

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0               # per-expert intermediate size
    n_shared_experts: int = 0       # deepseek-style shared experts
    router_norm_topk: bool = True   # normalize top-k scores (qwen3/mixtral style)

    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_n_groups: int = 1

    # --- hybrid (zamba2): shared attention block every N mamba layers ---
    attn_every: int = 0

    # --- enc-dec / frontend stubs ---
    encoder_layers: int = 0
    n_frontend_tokens: int = 0      # audio frames / vision patches (stub)
    frontend: str = ""              # "" | audio | vision

    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dualsparse: DualSparseConfig = dataclasses.field(default_factory=DualSparseConfig)

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.attn_kind != "none" or self.attn_every > 0

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for sanity tests."""
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.resolved_head_dim
        if self.family in ("ssm", "hybrid"):
            di, ds = self.d_inner, self.ssm_state
            # in_proj(z,x,B,C,dt) + out_proj + conv + dt/A/D
            conv_ch = di + 2 * self.ssm_n_groups * ds
            per_layer = d * (2 * di + 2 * self.ssm_n_groups * ds + self.ssm_heads) \
                + di * d + conv_ch * self.ssm_conv_width + 3 * self.ssm_heads
            blocks = per_layer * self.n_layers
            if self.attn_every:
                # one shared attention block (+ its own ffn) reused
                blocks += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
                blocks += 3 * d * self.d_ff
            return emb + blocks
        if self.attn_kind == "mla":
            attn = d * self.q_lora_rank \
                + self.q_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim) \
                + d * (self.kv_lora_rank + self.qk_rope_head_dim) \
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim) \
                + self.n_heads * self.v_head_dim * d
        else:
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        n_mats = 3 if self.mlp_kind == "swiglu" else 2
        if self.is_moe:
            ffn = self.n_experts * 3 * d * self.d_expert + d * self.n_experts
            ffn += self.n_shared_experts * 3 * d * self.d_expert
        else:
            ffn = n_mats * d * self.d_ff
        per_layer = attn + ffn
        total_layers = self.n_layers + self.encoder_layers
        if self.encoder_layers:  # decoder cross-attn
            per_layer_dec = attn * 2 + ffn
            return emb + self.encoder_layers * (attn + ffn) + self.n_layers * per_layer_dec
        return emb + total_layers * per_layer

    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant: same family/features, tiny dims."""
        kw = dict(
            n_layers=2,
            d_model=256,
            d_ff=512,
            vocab_size=512,
            head_dim=0,
        )
        if self.n_heads:
            kw["n_heads"] = 4
            kw["n_kv_heads"] = max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads < self.n_heads else 4
        if self.is_moe:
            kw["n_experts"] = 4
            kw["top_k"] = 2
            kw["d_expert"] = 128
            kw["n_shared_experts"] = min(self.n_shared_experts, 1)
        if self.attn_kind == "mla":
            kw["q_lora_rank"] = 64
            kw["kv_lora_rank"] = 32
            kw["qk_nope_head_dim"] = 16
            kw["qk_rope_head_dim"] = 16
            kw["v_head_dim"] = 16
        if self.ssm_state:
            kw["ssm_state"] = 16
            kw["ssm_head_dim"] = 32
        if self.attn_every:
            kw["attn_every"] = 2  # hybrid pattern still exercised with 2 layers
        if self.encoder_layers:
            kw["encoder_layers"] = 2
        if self.n_frontend_tokens:
            kw["n_frontend_tokens"] = 16
        if self.mrope_sections:
            # half head_dim = 32 with 4 heads@64 -> sections sum to 32
            kw["mrope_sections"] = (16, 8, 8)
        if self.sliding_window:
            kw["sliding_window"] = 64
        return replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
