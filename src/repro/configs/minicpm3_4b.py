"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B] — dense with MLA (multi-head latent
attention). 62L, d_model 2560, 40 heads, d_ff 6400, vocab 73448.
MLA ranks: q_lora 768, kv_lora 256, qk_nope 64, qk_rope 32, v_head 64."""
from .base import ModelConfig

CONFIGS = [
    ModelConfig(
        arch_id="minicpm3-4b",
        family="dense",
        source="hf:openbmb/MiniCPM3-4B",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_ff=6400,
        vocab_size=73448,
        attn_kind="mla",
        rope_theta=1e4,
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
        sliding_window=8192,
        tie_embeddings=True,
    )
]
