"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — fine-grained MoE: 128 experts,
top-8, per-expert d_ff 768. 48L, d_model 2048, 32 heads (kv=4), vocab 151936.

Primary target for the paper's technique: fine-grained experts with
normalized top-k gating, partitioned P=2 -> 256 sub-experts for S-ETP and
2T-Drop."""
from .base import ModelConfig, DualSparseConfig

CONFIGS = [
    ModelConfig(
        arch_id="qwen3-moe-30b-a3b",
        family="moe",
        source="hf:Qwen/Qwen3-30B-A3B",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,              # = d_expert for the MoE layer
        vocab_size=151936,
        attn_kind="gqa",
        rope_theta=1e6,
        n_experts=128,
        top_k=8,
        d_expert=768,
        router_norm_topk=True,
        sliding_window=8192,
        dualsparse=DualSparseConfig(enabled=True, partition_p=2,
                                    t_drop=0.08, t_major=0.07, t_minor=0.09,
                                    importance="abs_gate", load_aware=True),
    )
]
