"""Qwen2-7B [arXiv:2407.10671] — dense, GQA(kv=4), QKV bias.
28L, d_model 3584, 28 heads, d_ff 18944, vocab 152064."""
from .base import ModelConfig

CONFIGS = [
    ModelConfig(
        arch_id="qwen2-7b",
        family="dense",
        source="arXiv:2407.10671",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        attn_kind="gqa",
        rope_theta=1e6,
        qkv_bias=True,
        sliding_window=8192,
    )
]
