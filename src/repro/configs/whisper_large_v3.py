"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder; the conv/mel
frontend is a STUB (input_specs provides 1500 frame embeddings).
32 enc + 32 dec layers, d_model 1280, 20 MHA heads, d_ff 5120, vocab 51866.

long_500k is SKIPPED for this arch (bounded decoder context; see DESIGN.md §5)."""
from .base import ModelConfig

CONFIGS = [
    ModelConfig(
        arch_id="whisper-large-v3",
        family="audio",
        source="arXiv:2212.04356",
        n_layers=32,            # decoder layers
        encoder_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        attn_kind="gqa",
        mlp_kind="gelu",        # MHA == GQA with kv=heads
        frontend="audio",
        n_frontend_tokens=1500, # mel frames after conv downsample (stub)
        tie_embeddings=True,
    )
]
