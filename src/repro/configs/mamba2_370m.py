"""Mamba2-370m [arXiv:2405.21060] — attention-free SSM with SSD
(state-space duality). 48L, d_model 1024, ssm_state 128, vocab 50280."""
from .base import ModelConfig

CONFIGS = [
    ModelConfig(
        arch_id="mamba2-370m",
        family="ssm",
        source="arXiv:2405.21060",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        attn_kind="none",
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        tie_embeddings=True,
    )
]
