"""StarCoder2-3B [arXiv:2402.19173] — dense, GQA(kv=2), RoPE.
30L, d_model 3072, 24 heads, d_ff 12288, vocab 49152."""
from .base import ModelConfig

CONFIGS = [
    ModelConfig(
        arch_id="starcoder2-3b",
        family="dense",
        source="arXiv:2402.19173",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        d_ff=12288,
        vocab_size=49152,
        attn_kind="gqa",
        mlp_kind="gelu",
        rope_theta=1e5,
        qkv_bias=True,
        sliding_window=4096,
    )
]
