"""Synthetic-weight layouts of the paper's own evaluation models, used by the
benchmark harness (Tables 1-3, Figs 4-13). Reduced dims, faithful topology:

- mixtral-8x7b-lite : 8 experts, top-2, coarse experts  (Mixtral-8x7B [21])
- olmoe-lite        : 64 experts, top-8, fine-grained   (OLMoE [35])
- dsv2-lite-lite    : 64 routed + 2 shared experts, top-6 (DeepSeek-V2-Lite [28])
"""
from .base import ModelConfig, DualSparseConfig

CONFIGS = [
    ModelConfig(
        arch_id="mixtral-8x7b-lite",
        family="moe",
        source="arXiv:2401.04088 (reduced layout)",
        n_layers=4,
        d_model=256,
        n_heads=8,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=1024,
        attn_kind="gqa",
        n_experts=8,
        top_k=2,
        d_expert=512,
        router_norm_topk=True,
        dualsparse=DualSparseConfig(enabled=True, partition_p=2,
                                    t_drop=0.30, t_major=0.29, t_minor=0.31),
    ),
    ModelConfig(
        arch_id="olmoe-lite",
        family="moe",
        source="OLMoE [arXiv:2409.02060] (reduced layout)",
        n_layers=4,
        d_model=256,
        n_heads=8,
        n_kv_heads=8,
        d_ff=256,
        vocab_size=1024,
        attn_kind="gqa",
        n_experts=64,
        top_k=8,
        d_expert=256,
        router_norm_topk=True,
        dualsparse=DualSparseConfig(enabled=True, partition_p=2,
                                    t_drop=0.08, t_major=0.07, t_minor=0.09),
    ),
    ModelConfig(
        arch_id="dsv2-lite-lite",
        family="moe",
        source="DeepSeek-V2-Lite [arXiv:2405.04434] (reduced layout)",
        n_layers=4,
        d_model=256,
        n_heads=8,
        n_kv_heads=8,
        d_ff=256,
        vocab_size=1024,
        attn_kind="gqa",
        n_experts=64,
        top_k=6,
        d_expert=256,
        n_shared_experts=2,
        router_norm_topk=False,    # deepseek-v2 does not renormalize top-k
        dualsparse=DualSparseConfig(enabled=True, partition_p=2,
                                    t_drop=0.12, t_major=0.11, t_minor=0.13,
                                    importance="abs_gate_up"),
    ),
]
