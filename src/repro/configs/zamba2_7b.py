"""Zamba2-7B [arXiv:2411.15242] — hybrid Mamba2 backbone with a shared
attention block interleaved periodically. 81 Mamba2 layers, d_model 3584,
the shared attention block uses 32 MHA heads (kv=32), its FFN d_ff=14336,
vocab 32000, ssm_state=64."""
from .base import ModelConfig

CONFIGS = [
    ModelConfig(
        arch_id="zamba2-7b",
        family="hybrid",
        source="arXiv:2411.15242",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,
        vocab_size=32000,
        attn_kind="gqa",
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        attn_every=6,          # shared attention block applied every 6 mamba layers
        sliding_window=8192,   # used by the long_500k swa variant of the shared block
        tie_embeddings=True,
    )
]
