"""DBRX-132B [hf:databricks/dbrx-base] — coarse-expert MoE: 16 experts,
top-4, per-expert d_ff 10752. 40L, d_model 6144, 48 heads (kv=8),
vocab 100352. The coarse experts make it the Mixtral-like case from the
paper: partition P has the biggest effect here."""
from .base import ModelConfig, DualSparseConfig

CONFIGS = [
    ModelConfig(
        arch_id="dbrx-132b",
        family="moe",
        source="hf:databricks/dbrx-base",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab_size=100352,
        attn_kind="gqa",
        rope_theta=5e5,
        n_experts=16,
        top_k=4,
        d_expert=10752,
        router_norm_topk=True,
        sliding_window=8192,
        dualsparse=DualSparseConfig(enabled=True, partition_p=2,
                                    t_drop=0.15, t_major=0.14, t_minor=0.16,
                                    importance="abs_gate", load_aware=True),
    )
]
