"""Synthetic data pipeline (no datasets ship in this container).

Produces deterministic, seedable token streams with a Zipf-like unigram
distribution plus Markov bigram structure so language-model training has
actual learnable signal (loss decreases), and a calibration sampler used by
neuron-importance profiling (paper §4.2b profiles on MMLU; here the
calibration stream is drawn from the same synthetic distribution).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Markov bigram source: P(t | prev) ∝ zipf(t) * affinity(prev, t)."""
    vocab_size: int
    seed: int = 0
    n_clusters: int = 16
    zipf_a: float = 1.2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab_size + 1)
        self.unigram = (ranks ** (-self.zipf_a))
        self.unigram /= self.unigram.sum()
        # each token belongs to a cluster; bigrams prefer same-cluster tokens
        self.cluster = rng.integers(0, self.n_clusters, self.vocab_size)

    def sample_batch(self, rng_key, batch: int, seq: int) -> Dict[str, jax.Array]:
        """Vectorized sampling: cluster-boosted resampling of iid zipf."""
        k1, k2, k3 = jax.random.split(rng_key, 3)
        uni = jnp.asarray(self.unigram)
        logits = jnp.log(uni)
        base = jax.random.categorical(k1, logits, shape=(batch, seq + 1))
        # with prob 0.5, resample the token from its predecessor's cluster
        clusters = jnp.asarray(self.cluster)
        prev_cluster = clusters[base[:, :-1]]
        same = clusters[None, None, :] == prev_cluster[..., None]
        boosted = jnp.where(same, logits[None, None, :], -np.inf)
        resampled = jax.random.categorical(k2, boosted, axis=-1)
        use = jax.random.bernoulli(k3, 0.5, resampled.shape)
        nxt = jnp.where(use, resampled, base[:, 1:])
        tokens = jnp.concatenate([base[:, :1], nxt], axis=1)
        return {"tokens": tokens[:, :-1].astype(jnp.int32),
                "targets": tokens[:, 1:].astype(jnp.int32)}


@dataclasses.dataclass
class DataLoader:
    """Deterministic epoch-less loader; step -> batch."""
    source: SyntheticLM
    batch: int
    seq: int
    seed: int = 0

    def get_batch(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return self.source.sample_batch(key, self.batch, self.seq)

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.get_batch(step)
            step += 1


def calibration_activations(rng_key, n_tokens: int, d_model: int,
                            scale: float = 0.7):
    """Calibration activations entering a MoE layer (importance profiling).
    Anisotropic covariance mimics real hidden-state spectra."""
    k1, k2 = jax.random.split(rng_key)
    # power-law feature scales
    scales = (jnp.arange(1, d_model + 1) ** -0.3)
    x = jax.random.normal(k1, (n_tokens, d_model)) * scales[None, :]
    # a few dominant directions
    dirs = jax.random.normal(k2, (4, d_model)) / np.sqrt(d_model)
    coef = jax.random.normal(jax.random.fold_in(k2, 1), (n_tokens, 4))
    return (x + coef @ dirs * 3.0) * scale


def make_loader(cfg, batch: int, seq: int, seed: int = 0) -> DataLoader:
    return DataLoader(SyntheticLM(cfg.vocab_size, seed=seed), batch, seq,
                      seed=seed)
