"""Training driver.

Examples:
  # CPU-runnable ~100M-param fine-tune (reduced arch, synthetic data):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
      --reduced --steps 200 --batch 8 --seq 128

  # production lowering check (no execution):
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-moe-30b-a3b \
      --shape train_4k
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, list_archs
from repro.data import pipeline
from repro.models import model as M
from repro.optim import adamw, cosine_schedule
from repro import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--aux-coef", type=float, default=0.01,
                    help="MoE load-balance aux loss coefficient")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.arch_id} family={cfg.family} "
          f"params~{cfg.n_params()/1e6:.1f}M reduced={args.reduced}")

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(key, cfg)
    opt = adamw(cosine_schedule(args.lr, args.steps, warmup=args.steps // 20))
    opt_state = opt.init(params)
    loader = pipeline.make_loader(cfg, args.batch, args.seq, seed=args.seed)
    step_fn = jax.jit(M.make_train_step(
        cfg, opt, aux_coef=args.aux_coef if cfg.is_moe else 0.0))

    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        start = ckpt.latest_step(args.ckpt_dir)
        state = ckpt.restore_checkpoint(
            args.ckpt_dir, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"restored step {start}")

    t0 = time.time()
    for i in range(start, args.steps):
        batch = loader.get_batch(i)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if (i + 1) % args.log_every == 0 or i == start:
            dt = time.time() - t0
            print(f"step {i+1:5d}  loss {float(loss):.4f}  "
                  f"({dt / max(i + 1 - start, 1):.2f}s/step)", flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            ckpt.save_checkpoint(args.ckpt_dir, i + 1,
                                 {"params": params, "opt": opt_state})
    print("done")


if __name__ == "__main__":
    main()
