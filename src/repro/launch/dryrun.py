# The dry-run (and ONLY the dry-run) builds the production mesh out of 512
# placeholder host devices. These two lines MUST run before any other import
# (jax locks the device count on first init).
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.launch import mesh as mesh_mod
from repro.launch import specs
from repro.launch.hlo_analysis import roofline_terms
from repro.models import model as M
from repro.models.transformer import DistContext
from repro.optim import adamw

# (arch, shape) pairs that do not lower, with the DESIGN.md §5 reason.
SKIPS = {
    ("whisper-large-v3", "long_500k"):
        "enc-dec with bounded decoder context; 500k decode is architecturally"
        " meaningless (DESIGN.md §5)",
}


def build_dist(cfg: ModelConfig, kind: str, mesh) -> DistContext:
    """MoE archs: S-ETP EP always; the DualSparse inference system as a
    SparsityPolicy (load_aware when the config asks for it, else 2t) on the
    serving paths."""
    from repro.core.policy import make_policy
    serving = kind in ("prefill", "decode")
    pol = None
    if cfg.is_moe and cfg.dualsparse.enabled and serving:
        name = "load_aware" if cfg.dualsparse.load_aware else "2t"
        pol = make_policy(name, cfg.dualsparse)
    return DistContext(mesh=mesh, moe_impl="setp", policy=pol,
                       remat=(kind == "train"), remat_policy="dots")


def abstract_state(cfg: ModelConfig, shape: InputShape, mesh):
    """(abstract args, in_shardings, step_fn) for the given shape kind."""
    kind = shape.kind
    window = specs.decode_window(cfg, shape)
    dist = build_dist(cfg, kind, mesh)
    n_ep = mesh.shape["model"]

    if kind == "train":
        params, axes = M.abstract_params_and_axes(cfg, jnp.float32)
    else:
        params, axes = M.abstract_params_and_axes(cfg, jnp.bfloat16)
        if dist.policy is not None and dist.policy.partition_p > 1:
            def xf(p):
                calib = jnp.zeros((256, cfg.d_model), jnp.float32)
                return dist.policy.prepare(p, cfg, calib,
                                           n_ep_devices=n_ep)[0]
            new_params = jax.eval_shape(xf, params)
            axes = _retree_axes(axes, new_params)
            params = new_params
        elif cfg.is_moe:
            # plain S-ETP still needs strided placement (id-preserving shapes)
            pass
    p_shard = specs.param_shardings(cfg, params, axes, mesh)

    if kind == "train":
        opt = adamw(1e-4)
        opt_state = jax.eval_shape(opt.init, params)
        # AdamWState is a NamedTuple: params shardings map onto mu/nu
        from repro.optim.adamw import AdamWState
        o_shard = AdamWState(
            step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            mu=p_shard, nu=p_shard)
        batch = specs.abstract_batch(cfg, shape.global_batch, shape.seq_len,
                                     "train")
        b_shard = specs.batch_shardings(cfg, batch, mesh)
        step = M.make_train_step(cfg, opt, window=window, dist=dist)
        return (params, opt_state, batch), (p_shard, o_shard, b_shard), step

    if kind == "prefill":
        batch = specs.abstract_batch(cfg, shape.global_batch, shape.seq_len,
                                     "prefill")
        b_shard = specs.batch_shardings(cfg, batch, mesh)
        step = M.make_prefill_step(cfg, cache_len=shape.seq_len,
                                   window=window, dist=dist)
        return (params, batch), (p_shard, b_shard), step

    # decode: ONE token against a seq_len cache
    ctx = min(window, shape.seq_len) if window else shape.seq_len
    cache = M.abstract_cache(cfg, shape.global_batch, shape.seq_len,
                             window=window)
    c_shard = specs.cache_shardings(cfg, cache, mesh)
    token = specs.sds((shape.global_batch, 1), jnp.int32)
    t_shard = specs.batch_shardings(cfg, {"t": token}, mesh)["t"]
    step = M.make_serve_step(cfg, window=window, dist=dist)
    return (params, token, cache), (p_shard, t_shard, c_shard), step


def _retree_axes(axes, new_params):
    """Axes tree for transformed params: same structure, reuse where leaf
    paths match, default replicated-expert axes for the moe leaves."""
    flat_new = jax.tree_util.tree_flatten_with_path(new_params)[0]
    flat_old = dict(jax.tree_util.tree_flatten_with_path(
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))[0])
    out = []
    for path, leaf in flat_new:
        if path in flat_old:
            out.append(flat_old[path])
        else:
            out.append((None,) * len(leaf.shape))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(new_params), out)


def _per_device_param_bytes(params_abs, shardings) -> int:
    """Per-device bytes of the (sharded) param arguments."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(params_abs),
                        jax.tree.leaves(shardings,
                                        is_leaf=lambda x: hasattr(x, "spec"))):
        n = leaf.size * jnp.dtype(leaf.dtype).itemsize
        shard = 1
        for entry in sh.spec:
            axes = entry if isinstance(entry, tuple) else (entry,)
            for ax in axes:
                if ax is not None:
                    shard *= sh.mesh.shape[ax]
        total += n // max(shard, 1)
    return total


def run_one(arch: str, shape_name: str, multi_pod: bool,
            donate: bool = True) -> Dict[str, Any]:
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
    }
    if (arch, shape_name) in SKIPS:
        rec.update(status="skipped", reason=SKIPS[(arch, shape_name)])
        return rec
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    try:
        t0 = time.time()
        args, shardings, step = abstract_state(cfg, shape, mesh)
        jitted = jax.jit(step, in_shardings=shardings,
                         donate_argnums=tuple(range(len(args))) if donate
                         and shape.kind != "prefill" else ())
        with mesh_mod.use_mesh(mesh):
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        # XLA's HloCostAnalysis counts while bodies once, so flops/bytes come
        # from our own trip-count-scaled HLO analysis (hlo_analysis.py).
        from repro.launch.hlo_analysis import analyze_hlo
        costs = analyze_hlo(compiled.as_text())
        rec["flops"] = costs.flops                      # per device
        rec["hlo_bytes_proxy"] = costs.hbm_bytes        # upper-bound proxy
        ca = compiled.cost_analysis() or {}
        rec["xla_flops_1iter"] = float(ca.get("flops", -1.0))
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            }
        except Exception as e:  # CPU backend may not implement it
            rec["memory"] = {"error": str(e)}
        rec["collectives"] = {
            "bytes_by_kind": costs.bytes_by_kind,
            "count_by_kind": costs.count_by_kind,
            "total_bytes": costs.collective_bytes,
        }
        # memory term: every argument read once + outputs written + temps
        # touched twice (activation write+read). The CPU backend's
        # FloatNormalization pass materializes f32 copies of every bf16
        # weight (a compile-target artifact that does not exist on TPU), so
        # for bf16-param steps we subtract that known 2x-param temp before
        # weighting temps. Params' per-device bytes follow from the
        # in_shardings.
        mem = rec["memory"]
        traffic = 0.0
        if mem.get("argument_bytes") is not None:
            temp = mem.get("temp_bytes") or 0
            if shape.kind != "train":
                pdev = _per_device_param_bytes(args[0], shardings[0])
                rec["param_bytes_per_device"] = pdev
                temp = max(temp - 2 * pdev, 0)
            rec["temp_bytes_adjusted"] = temp
            traffic = (mem["argument_bytes"] + (mem.get("output_bytes") or 0)
                       + 2 * temp)
        rec["hbm_traffic_bytes"] = traffic
        rec["roofline"] = roofline_terms(
            costs.flops, traffic, costs.collective_bytes, 1,
            peak_flops=mesh_mod.PEAK_FLOPS_BF16, hbm_bw=mesh_mod.HBM_BW,
            ici_bw=mesh_mod.ICI_BW)
        rec["n_chips"] = n_chips
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    return rec


def main():
    ap = argparse.ArgumentParser(description="Multi-pod dry-run")
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS + ["all"], default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES) + ["all"],
                    default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape x mesh) via subprocesses")
    ap.add_argument("--out", default=None)
    ap.add_argument("--jobs", type=int, default=2)
    args = ap.parse_args()

    if args.all or args.arch == "all" or args.shape == "all":
        archs = ASSIGNED_ARCHS if args.arch in (None, "all") else [args.arch]
        shapes = list(INPUT_SHAPES) if args.shape in (None, "all") \
            else [args.shape]
        meshes = [False, True] if (args.both_meshes or args.all) \
            else [args.multi_pod]
        combos = [(a, s, m) for a in archs for s in shapes for m in meshes]
        _run_many(combos, args.out, args.jobs)
        return

    rec = run_one(args.arch, args.shape, args.multi_pod)
    line = json.dumps(rec)
    if args.out:
        with open(args.out, "a") as f:
            f.write(line + "\n")
    summary = {k: rec.get(k) for k in
               ("arch", "shape", "mesh", "status", "compile_s", "flops",
                "hlo_bytes", "error")}
    print(json.dumps(summary, indent=1))
    if rec["status"] == "ok":
        print("collectives:", json.dumps(rec["collectives"]))
        print("memory:", json.dumps(rec["memory"]))
        print("roofline(s):", json.dumps(rec["roofline"]))
    elif rec["status"] == "error":
        print(rec.get("traceback", ""), file=sys.stderr)
        sys.exit(1)


def _run_many(combos, out: Optional[str], jobs: int):
    """Subprocess per combo (isolates compile memory), bounded parallelism."""
    procs: list = []
    pending = list(combos)
    results = []

    def launch(combo):
        a, s, m = combo
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", a, "--shape", s] + (["--multi-pod"] if m else [])
        if out:
            cmd += ["--out", out]
        env = dict(os.environ)
        return combo, subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                       stderr=subprocess.DEVNULL, env=env)

    while pending or procs:
        while pending and len(procs) < jobs:
            procs.append(launch(pending.pop(0)))
        done = [p for p in procs if p[1].poll() is not None]
        for combo, proc in done:
            procs.remove((combo, proc))
            ok = proc.returncode == 0
            print(f"[{'OK' if ok else 'FAIL'}] {combo}", flush=True)
            results.append((combo, ok))
        if not done:
            time.sleep(2)
    n_ok = sum(1 for _, ok in results if ok)
    print(f"{n_ok}/{len(results)} combos lowered+compiled")


if __name__ == "__main__":
    main()
