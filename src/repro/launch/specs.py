"""Abstract input construction (ShapeDtypeStruct) + shardings for dry-runs.

input_specs() mirrors models.model.make_batch / init_cache but produces
weak-type-correct ShapeDtypeStructs — nothing is ever allocated.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, InputShape
from ..distributed.sharding import batch_spec, spec_for


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def decode_window(cfg: ModelConfig, shape: InputShape) -> int:
    """Sliding window used for the long-context decode variant (DESIGN §5)."""
    if shape.name == "long_500k" and cfg.sliding_window:
        return cfg.sliding_window
    return 0


def abstract_batch(cfg: ModelConfig, batch: int, seq: int, kind: str,
                   compute_dtype=jnp.bfloat16) -> Dict[str, Any]:
    out: Dict[str, Any] = {"tokens": sds((batch, seq), jnp.int32)}
    if kind == "train":
        out["targets"] = sds((batch, seq), jnp.int32)
    if cfg.frontend == "vision":
        out["frontend"] = sds((batch, cfg.n_frontend_tokens, cfg.d_model),
                              compute_dtype)
    if cfg.frontend == "audio":
        out["audio_embeds"] = sds((batch, cfg.n_frontend_tokens, cfg.d_model),
                                  compute_dtype)
    return out


def batch_shardings(cfg: ModelConfig, batch_abs, mesh: Mesh):
    def one(path, leaf):
        extra = (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, batch_spec(leaf.shape[0], mesh, extra))
    return jax.tree_util.tree_map_with_path(one, batch_abs)


# ---------------------------------------------------------------------------
# Cache shardings (decode)
# ---------------------------------------------------------------------------

def _window_axes(w: int, batch_sharded: bool, want_model: bool,
                 data_n: int, model_n: int):
    """Mesh axes for the KV window dim: 'data' when the batch can't use it,
    'model' (context-parallel) when heads can't; only while divisible."""
    axes = []
    prod = 1
    if not batch_sharded and w % (prod * data_n) == 0:
        axes.append("data")
        prod *= data_n
    if want_model and w % (prod * model_n) == 0:
        axes.append("model")
        prod *= model_n
    return tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)


def cache_shardings(cfg: ModelConfig, cache_abs, mesh: Mesh):
    """Sharding specs for the layer-stacked decode cache.

    Layout rules:
      * batch dim -> (pod, data) when divisible
      * heads / latent feature dims -> model when divisible
      * when batch cannot shard over data (long_500k B=1), the KV *window*
        dim shards over data instead — context-parallel decode.
    """
    model_n = mesh.shape.get("model", 1)
    data_n = mesh.shape.get("data", 1)

    def one(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        shp = leaf.shape
        if name == "pos" or len(shp) == 0:
            return NamedSharding(mesh, P())
        bspec = batch_spec(shp[1], mesh) if len(shp) >= 2 else P(None)
        b_axes = bspec[0] if len(bspec) else None
        batch_sharded = b_axes is not None
        if name in ("k", "v") and len(shp) == 5:        # (L,B,W,H,D)
            h_ax = "model" if shp[3] % model_n == 0 else None
            w_ax = _window_axes(shp[2], batch_sharded, h_ax is None,
                                data_n, model_n)
            return NamedSharding(mesh, P(None, b_axes, w_ax, h_ax, None))
        if name in ("c", "kr") and len(shp) == 4:       # (L,B,W,r) MLA
            w_ax = _window_axes(shp[2], batch_sharded, True, data_n, model_n)
            return NamedSharding(mesh, P(None, b_axes, w_ax, None))
        if name in ("cross_k", "cross_v") and len(shp) == 5:
            h_ax = "model" if shp[3] % model_n == 0 else None
            return NamedSharding(mesh, P(None, b_axes, None, h_ax, None))
        if name == "ssm" and len(shp) == 5:             # (L,B,H,P,N)
            h_ax = "model" if shp[2] % model_n == 0 else None
            return NamedSharding(mesh, P(None, b_axes, h_ax, None, None))
        if name == "conv" and len(shp) == 4:            # (L,B,w,C)
            c_ax = "model" if shp[3] % model_n == 0 else None
            return NamedSharding(mesh, P(None, b_axes, None, c_ax))
        # fallback: replicate
        return NamedSharding(mesh, P(*([None] * len(shp))))

    return jax.tree_util.tree_map_with_path(one, cache_abs)


def param_shardings(cfg: ModelConfig, params_abs, axes_tree, mesh: Mesh):
    def one(axes, leaf):
        return NamedSharding(mesh, spec_for(axes, leaf.shape, mesh))
    return jax.tree.map(one, axes_tree, params_abs,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))
