"""Serving driver: requests through the DualSparse-MoE serving engines.

Sparsity is selected with ``--policy`` (the SparsityPolicy registry):
  none       — plain top-k MoE
  1t         — 1T-Drop (all-or-nothing per token-expert pair)
  2t         — partition + reconstruction + 2T-Drop (paper §4.2)
  load_aware — 2T with load-aware per-device thresholds (§4.3)
  per_layer  — 2T with per-layer thresholds calibrated to --drop-target

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
      --reduced --requests 8 --prompt-len 64 --new-tokens 32 --policy 2t
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
      --reduced --engine continuous --slots 4 --requests 8 \
      --policy per_layer --drop-target 0.25
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.policy import POLICIES, make_policy
from repro.data.pipeline import SyntheticLM, calibration_activations
from repro.models import model as M
from repro.serving import (ContinuousBatchingEngine, GenerationConfig,
                           PagedEngine, ServingEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", default="sync",
                    choices=("sync", "continuous", "paged"),
                    help="synchronized batches, slot-based continuous "
                         "batching with mid-decode admission, or paged KV "
                         "(page-table cache + chunked prefill + prefix cache)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--batch-size", type=int, default=8,
                    help="sync batch size / continuous slot count")
    ap.add_argument("--slots", type=int, default=0,
                    help="continuous/paged engine slot count "
                         "(0 = --batch-size)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged engine: tokens per KV page")
    ap.add_argument("--chunk-size", type=int, default=32,
                    help="paged engine: prompt tokens per prefill chunk")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="paged engine: disable cross-request prefix reuse")
    ap.add_argument("--policy", default=None, choices=sorted(POLICIES),
                    help="sparsity policy (default: none)")
    ap.add_argument("--drop-target", type=float, default=None,
                    help="calibrate policy thresholds to this drop rate on "
                         "synthetic calibration activations")
    ap.add_argument("--dualsparse", action="store_true",
                    help="DEPRECATED alias for --policy 2t")
    ap.add_argument("--fused-pipeline", action="store_true", default=None,
                    help="force MoE layers through the single fused "
                         "streamed Pallas dispatch->FFN->combine kernel "
                         "(no (E, C, d) HBM buffer, no unpermute "
                         "read-back). Default is AUTO: the per-shape "
                         "heuristic (core.dispatch.prefer_fused_pipeline) "
                         "picks fused wherever the bench shows a win — "
                         "always on TPU/GPU, with use_kernel on CPU")
    ap.add_argument("--no-fused-pipeline", dest="fused_pipeline",
                    action="store_false",
                    help="force the buffer path (disable the fused kernel "
                         "even where the heuristic would pick it)")
    ap.add_argument("--seed", type=int, default=0)
    # observability (repro.obs)
    ap.add_argument("--no-metrics", action="store_true",
                    help="disable the traced on-device metrics seam "
                         "(cache falls back to the legacy moe_overflow "
                         "scalar)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text exposition on this port "
                         "while requests run (0 = ephemeral); the driver "
                         "self-scrapes /metrics at the end and fails if "
                         "the payload does not round-trip")
    ap.add_argument("--metrics-log", default=None, metavar="PATH",
                    help="append one JSON metrics snapshot line after the "
                         "run ('-' = stdout)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the engine span trace as Chrome-trace JSON "
                         "(load in chrome://tracing or Perfetto)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the whole run "
                         "into this directory (TensorBoard/XProf format)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(key, cfg)

    policy_name = args.policy
    if policy_name is None and args.dualsparse:
        print("--dualsparse is deprecated; use --policy 2t")
        policy_name = "2t"
    policy_name = policy_name or "none"

    dist = None
    # an explicit --fused-pipeline/--no-fused-pipeline needs a policy object
    # to carry the hint, so it also builds one for --policy none
    force_dist = policy_name != "none" or args.fused_pipeline is not None
    if force_dist and cfg.is_moe and cfg.dualsparse.enabled:
        policy = make_policy(policy_name, cfg.dualsparse,
                             drop_target=args.drop_target,
                             fused_pipeline=args.fused_pipeline)
        calib = calibration_activations(jax.random.PRNGKey(7), 512,
                                        cfg.d_model)
        params, policy = policy.prepare(params, cfg, calib)
        from repro.models.transformer import DistContext
        from repro.launch.mesh import make_host_mesh
        # single-host: policy-driven dispatch path without shard_map
        dist = DistContext(mesh=make_host_mesh(1), moe_impl="dispatch",
                           policy=policy)
        print(f"sparsity policy {policy.name!r}: partition P="
              f"{policy.partition_p}"
              + (f", drop_target={args.drop_target}"
                 if args.drop_target is not None else ""))

    src = SyntheticLM(cfg.vocab_size, seed=args.seed)
    prompts = [np.asarray(src.sample_batch(
        jax.random.fold_in(key, i), 1, args.prompt_len)["tokens"][0])
        for i in range(args.requests)]

    metrics = not args.no_metrics
    if args.engine == "continuous":
        eng = ContinuousBatchingEngine(
            cfg, params, n_slots=args.slots or args.batch_size,
            max_prompt_len=args.prompt_len, max_new_tokens=args.new_tokens,
            dist=dist, metrics=metrics)
    elif args.engine == "paged":
        eng = PagedEngine(
            cfg, params, n_slots=args.slots or args.batch_size,
            page_size=args.page_size, chunk_size=args.chunk_size,
            max_prompt_len=args.prompt_len, max_new_tokens=args.new_tokens,
            dist=dist, prefix_cache=not args.no_prefix_cache,
            metrics=metrics)
    else:
        eng = ServingEngine(cfg, params, batch_size=args.batch_size,
                            max_prompt_len=args.prompt_len,
                            max_new_tokens=args.new_tokens, dist=dist,
                            metrics=metrics)

    server = None
    if args.metrics_port is not None:
        from repro.obs import MetricsServer
        server = MetricsServer(eng.metrics, port=args.metrics_port)
        server.start()
        print(f"metrics: serving Prometheus exposition at {server.url}")
    if args.profile_dir:
        jax.profiler.start_trace(args.profile_dir)
    t0 = time.time()
    try:
        results = eng.generate(prompts, GenerationConfig(
            max_new_tokens=args.new_tokens, seed=args.seed))
    finally:
        if args.profile_dir:
            jax.profiler.stop_trace()
            print(f"profiler trace written to {args.profile_dir}")
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests, {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok / dt:.1f} tok/s) "
          f"policy={policy_name} moe_overflow={eng.overflow_pairs}")
    timing = eng.timing
    print(f"  compile={timing['compile_s']:.2f}s "
          f"({timing['compile_steps']} traced steps) "
          f"steady_step={timing['steady_step_s'] * 1e3:.1f}ms "
          f"over {timing['steady_steps']} steps")
    if args.engine == "continuous":
        print(f"  slots={eng.n_slots} admitted={eng.n_admitted} "
              f"decode_steps={eng.decode_steps} "
              f"max_concurrency={eng.max_concurrency} "
              f"traces(prefill={eng.prefill_traces}, "
              f"decode={eng.decode_traces})")
    elif args.engine == "paged":
        print(f"  slots={eng.n_slots} admitted={eng.n_admitted} "
              f"chunk_steps={eng.chunk_steps} "
              f"decode_steps={eng.decode_steps} "
              f"prefix_hit_rate={eng.prefix_hit_rate:.2f} "
              f"traces(chunk={eng.chunk_traces}, "
              f"decode={eng.decode_traces})")
    for r in results[:4]:
        print(f"  req{r.uid}: {r.tokens[:12]}...")

    if args.metrics_log:
        from repro.obs import snapshot_json_line
        line = snapshot_json_line(eng.metrics(), arch=args.arch,
                                  engine=args.engine, policy=policy_name)
        if args.metrics_log == "-":
            print(line)
        else:
            with open(args.metrics_log, "a") as f:
                f.write(line + "\n")
            print(f"metrics: snapshot appended to {args.metrics_log}")
    if args.trace_out:
        eng.tracer.write_chrome_trace(args.trace_out)
        print(f"metrics: span trace written to {args.trace_out} "
              f"({len(eng.tracer.events())} events)")
    if server is not None:
        import urllib.request
        from repro.obs import parse_prometheus
        with urllib.request.urlopen(server.url) as resp:
            text = resp.read().decode()
        snap = parse_prometheus(text)
        n_series = (len(snap.counters) + len(snap.gauges)
                    + len(snap.histograms))
        server.stop()
        if n_series == 0:
            raise SystemExit("metrics scrape FAILED: no series parsed")
        print(f"metrics scrape ok ({n_series} series)")


if __name__ == "__main__":
    main()
