"""Production mesh construction (single pod 16x16 = 256 chips; multi-pod
2x16x16 = 512). Defined as functions so importing this module never touches
jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 4):
    """Small mesh over whatever host devices exist (tests/benchmarks)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e-class hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
