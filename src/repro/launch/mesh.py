"""Production mesh construction (single pod 16x16 = 256 chips; multi-pod
2x16x16 = 512). Defined as functions so importing this module never touches
jax device state."""
from __future__ import annotations

import jax


def make_mesh_auto(shape, axes):
    """Version-tolerant jax.make_mesh: newer JAX wants explicit Auto axis
    types; older JAX has no AxisType and every axis is implicitly auto."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def use_mesh(mesh):
    """Version-tolerant ambient-mesh context manager: ``jax.set_mesh`` on
    newer JAX; on older JAX the Mesh object itself is the context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def make_host_mesh(model: int = 4):
    """Small mesh over whatever host devices exist (tests/benchmarks)."""
    n = len(jax.devices())
    model = min(model, n)
    return make_mesh_auto((n // model, model), ("data", "model"))


# TPU v5e-class hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
