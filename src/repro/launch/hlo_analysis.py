"""Post-SPMD HLO analysis: FLOPs, HBM-traffic proxy, and collective bytes,
all scaled by while-loop trip counts.

Why not compiled.cost_analysis()? XLA's HloCostAnalysis visits each while
body ONCE — a 28-layer scan reports 1/28th of the FLOPs. The dry-run needs
whole-step numbers, so we parse the partitioned HLO text ourselves:

  * dot instructions -> 2 * elems(result) * K flops (K from the printed
    lhs_contracting_dims and the operand's defining shape)
  * every non-trivial instruction -> result+operand bytes (HBM proxy)
  * all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute -> result bytes (interconnect traffic)

Each computation's totals are multiplied by its loop multiplier, propagated
through while(body=...) edges (trip count from the backend_config
``known_trip_count`` annotation) and call/fusion edges (x1).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s([a-z][\w\-]*)\((.*)$")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems_total, total = 0, 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems_total += n
        total += n * DTYPE_BYTES[dt]
    return elems_total, total


def shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class ModuleCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, float] = field(default_factory=dict)

    def as_dict(self):
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": self.collective_bytes,
                "bytes_by_kind": self.bytes_by_kind,
                "count_by_kind": self.count_by_kind}


@dataclass
class _Block:
    name: str
    is_entry: bool
    lines: List[str]
    shapes: Dict[str, str] = field(default_factory=dict)
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    coll_n: Dict[str, float] = field(default_factory=dict)
    # edges: (callee, multiplier)
    edges: List[Tuple[str, int]] = field(default_factory=list)


def _parse_blocks(hlo: str) -> Dict[str, _Block]:
    blocks: Dict[str, _Block] = {}
    lines = hlo.splitlines()
    i = 0
    while i < len(lines):
        line = lines[i]
        m = _HEADER_RE.match(line)
        if m and line.rstrip().endswith("{"):
            name = m.group(1)
            is_entry = line.startswith("ENTRY")
            depth = line.count("{") - line.count("}")
            body: List[str] = []
            i += 1
            while i < len(lines) and depth > 0:
                depth += lines[i].count("{") - lines[i].count("}")
                body.append(lines[i])
                i += 1
            blocks[name] = _Block(name, is_entry, body)
        else:
            i += 1
    return blocks


def _analyze_block(b: _Block):
    for line in b.lines:
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, shape_str, op, rest = m.groups()
        b.shapes[name] = shape_str
        if op in _SKIP_BYTES_OPS:
            continue
        elems, rbytes = shape_elems_bytes(shape_str)
        operand_names = re.findall(r"%([\w.\-]+)", rest.split("),")[0])
        operand_bytes = [shape_elems_bytes(b.shapes[o])[1]
                         for o in operand_names if o in b.shapes]
        obytes = sum(operand_bytes)
        # HBM-traffic special cases (see module docstring):
        if op == "dynamic-update-slice" and len(operand_bytes) >= 2:
            # in-place update: traffic = read+write of the slice only
            b.bytes += 2 * operand_bytes[1]
        elif op in ("fusion", "dynamic-slice", "gather"):
            # slicing fusions read only what they emit; clamp operand reads
            b.bytes += rbytes + min(obytes, 2 * rbytes)
        else:
            b.bytes += rbytes + obytes

        if op == "dot":
            ops_m = re.findall(r"%([\w.\-]+)", rest)
            cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            k = 1
            if ops_m and cd:
                lhs_shape = b.shapes.get(ops_m[0])
                if lhs_shape:
                    dims = shape_dims(lhs_shape)
                    for d in cd.group(1).split(","):
                        if d and int(d) < len(dims):
                            k *= dims[int(d)]
            b.flops += 2.0 * elems * k
        elif op in ("convolution",):
            b.flops += 2.0 * elems  # lower bound; convs unused in this repo
        elif op.replace("-start", "") in COLLECTIVE_KINDS:
            kind = op.replace("-start", "")
            b.coll[kind] = b.coll.get(kind, 0) + rbytes
            b.coll_n[kind] = b.coll_n.get(kind, 0) + 1

        # call graph edges
        wm = re.search(r"body=%?([\w.\-]+)", line)
        if op == "while" and wm:
            tm = re.search(r"known_trip_count\\?\"?:\s*\{\\?\"?n\\?\"?:"
                           r"\\?\"?(\d+)", line)
            trip = int(tm.group(1)) if tm else 1
            b.edges.append((wm.group(1), trip))
            cm = re.search(r"condition=%?([\w.\-]+)", line)
            if cm:
                b.edges.append((cm.group(1), trip))
        elif op in ("call", "fusion", "custom-call", "async-start"):
            km = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", line)
            if km:
                # fusion internals: count dot flops only (bytes are already
                # approximated at the call site by operand/result sizes)
                b.edges.append((km.group(1), 1))


def analyze_hlo(hlo: str) -> ModuleCosts:
    blocks = _parse_blocks(hlo)
    for b in blocks.values():
        _analyze_block(b)

    # propagate multipliers from the entry computation
    entry = next((b.name for b in blocks.values() if b.is_entry), None)
    mult: Dict[str, float] = {name: 0.0 for name in blocks}
    if entry is None:
        return ModuleCosts()
    mult[entry] = 1.0
    # topological-ish: repeat until fixpoint (call graphs are shallow)
    for _ in range(32):
        changed = False
        for b in blocks.values():
            if mult.get(b.name, 0) == 0:
                continue
            for callee, trip in b.edges:
                if callee in mult:
                    want = mult[b.name] * trip
                    # a callee may be invoked from several sites; take the sum
                    # only once per (caller, callee) — approximated by max
                    if want > mult[callee]:
                        mult[callee] = want
                        changed = True
        if not changed:
            break

    costs = ModuleCosts()
    for b in blocks.values():
        m = mult.get(b.name, 0.0)
        if m == 0:
            continue
        costs.flops += b.flops * m
        # bytes: fusion/reduce sub-computations are counted at call sites
        if not b.name.startswith("fused_") and not b.name.startswith("region_"):
            costs.hbm_bytes += b.bytes * m
        for kind, v in b.coll.items():
            costs.bytes_by_kind[kind] = costs.bytes_by_kind.get(kind, 0) + v * m
            costs.collective_bytes += v * m
        for kind, v in b.coll_n.items():
            costs.count_by_kind[kind] = costs.count_by_kind.get(kind, 0) + v * m
    return costs


def count_shape_instructions(hlo: str, dims, dtype: Optional[str] = None,
                             exclude_ops=("parameter",)) -> int:
    """Count HLO instructions (across ALL computations, fusion bodies
    included) whose RESULT contains an array of exactly ``dims``
    (optionally also matching ``dtype``, e.g. "f32").

    This is the robust form of "was a buffer of this shape materialized?":
    byte totals shift with unrelated lowering choices, but an
    (E, capacity, d) intermediate can only appear in the module if some
    instruction actually produces it — the assertion
    ``bench_moe_pipeline.py`` runs against the fused MoE path."""
    target = [int(d) for d in dims]
    n = 0
    for line in hlo.splitlines():
        m = _LINE_RE.match(line)
        if not m:
            continue
        _, shape_str, op, _ = m.groups()
        if op in exclude_ops:
            continue
        for sm in _SHAPE_RE.finditer(shape_str):
            if dtype is not None and sm.group(1) != dtype:
                continue
            got = [int(d) for d in sm.group(2).split(",") if d]
            if got == target:
                n += 1
                break
    return n


# Backwards-compatible helpers -------------------------------------------------

@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, float]

    @property
    def total_bytes(self):
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self):
        return sum(self.count_by_kind.values())

    def as_dict(self):
        return {"bytes_by_kind": self.bytes_by_kind,
                "count_by_kind": self.count_by_kind,
                "total_bytes": self.total_bytes,
                "total_count": self.total_count}


def collect_collectives(hlo: str) -> CollectiveStats:
    c = analyze_hlo(hlo)
    return CollectiveStats(c.bytes_by_kind, c.count_by_kind)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int, *, peak_flops: float, hbm_bw: float,
                   ici_bw: float, ici_links: int = 4) -> Dict[str, float]:
    """The three §Roofline terms in seconds, from PER-DEVICE numbers
    (n_chips=1) or whole-job numbers (n_chips=N)."""
    return {
        "t_compute": flops / (n_chips * peak_flops),
        "t_memory": hbm_bytes / (n_chips * hbm_bw),
        "t_collective": coll_bytes / (n_chips * ici_bw * ici_links),
    }
