"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_swiglu_ref(x, w1, w3, w2, counts_full=None, counts_major=None,
                       n_minor_start=None):
    """Grouped SwiGLU expert FFN with 2T-Drop row/neuron masking.

    x: (E, C, d) per-expert token buffers (rows beyond the valid count are
    padding). w1, w3: (E, d, f); w2: (E, f, d). Neuron layout after
    reconstruction: [0, n_minor_start) = MAJOR neurons, the rest MINOR
    (``n_minor_start`` defaults to f/2; pass f to disable the split).

    Row semantics (tokens sorted by mode within each expert buffer):
      rows [0, counts_full[e])                       -> full expert
      rows [counts_full[e], counts_full+counts_major) -> major half only
      remaining rows                                  -> padding (zero out)

    counts_full=None means all C rows are valid full-mode tokens.
    """
    E, C, d = x.shape
    f = w1.shape[-1]
    rows = jnp.arange(C)[None, :]                       # (1, C)
    if counts_full is None:
        counts_full = jnp.full((E,), C, jnp.int32)
        counts_major = jnp.zeros((E,), jnp.int32)
    if counts_major is None:
        counts_major = jnp.zeros((E,), jnp.int32)
    if n_minor_start is None:
        n_minor_start = f // 2
    full_ok = rows < counts_full[:, None]               # (E, C)
    any_ok = rows < (counts_full + counts_major)[:, None]

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w1))
    h = h * jnp.einsum("ecd,edf->ecf", x, w3)
    neuron_is_major = (jnp.arange(f) < n_minor_start)[None, None, :]
    row_mask = jnp.where(neuron_is_major, any_ok[..., None],
                         full_ok[..., None])
    h = h * row_mask.astype(h.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w2)
