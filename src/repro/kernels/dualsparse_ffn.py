"""Pallas TPU kernel: grouped SwiGLU expert FFN with dual-sparse block
skipping (the TPU adaptation of the paper's §4.2 Triton kernel).

Design (see DESIGN.md §3):
  * tokens are pre-sorted per expert buffer: FULL-mode rows first, then
    MAJOR-only rows, then padding. Neurons are pre-reconstructed so the
    MAJOR half occupies d_ff slots [0, f/2).
  * grid = (E, C/block_c, f/block_f); the f axis is innermost and
    accumulates into the (block_c, d) output tile resident in VMEM.
  * a (token-block, neuron-block) pair is SKIPPED with ``pl.when`` whenever
    no row of the block needs that neuron half:
        neuron block in MINOR half -> valid rows = counts_full[e]
        neuron block in MAJOR half -> valid rows = counts_full[e]+counts_major[e]
    so 2T-Drop's computation dropping becomes whole MXU tiles never issued —
    the tensor-granular saving the paper argues is what real hardware can
    actually cash in (vs. fine-grained sparsity).
  * within a partially-valid block, rows are masked by an iota compare
    (VPU-cheap) for exactness.

Block shapes default to (128, 128) — MXU-aligned; d (the contraction /
output width) stays whole per tile so each grid step is one
(block_c × d) @ (d × block_f) MXU matmul pair + one (block_c × block_f) @
(block_f × d) accumulation.

VMEM working set per step ≈ (block_c·d + 2·d·block_f + block_f·d +
block_c·d) · bytes — e.g. d=2048, blocks 128/128, bf16: ≈ 2.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .specs import BlockUse, KernelSpec, dtype_name


def _resolve_blocks(C: int, f: int, p_factor: int,
                    n_minor_start: int | None, block_c: int, block_f: int):
    """Shared geometry: clamp blocks to the logical dims, pad to block
    multiples, resolve the minor-half boundary. Returns a meta dict both
    kernel specs embed and both launches consume."""
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    pad_c, pad_f = (-C) % block_c, (-f) % block_f
    Cp, fp = C + pad_c, f + pad_f
    nf_sub = fp // block_f              # f-blocks per sub-expert
    n_f = p_factor * nf_sub             # f-blocks over the virtual width
    if n_minor_start is None:
        if p_factor > 1:
            n_minor_start = fp          # everything past sub-expert 0
        else:
            n_minor_start = f // 2 if f % 2 == 0 else f
    return dict(block_c=block_c, block_f=block_f, pad_c=pad_c, pad_f=pad_f,
                Cp=Cp, fp=fp, nf_sub=nf_sub, n_f=n_f,
                n_minor_start=n_minor_start, p_factor=p_factor)


def grouped_swiglu_kernel_spec(E: int, C: int, d: int, f: int, *,
                               dtype=jnp.float32, p_factor: int = 1,
                               n_minor_start: int | None = None,
                               block_c: int = 128,
                               block_f: int = 128) -> KernelSpec:
    """Static launch description of ``grouped_swiglu_pallas`` for logical
    shapes x: (E, C, d), w1/w3: (E*p_factor, d, f), w2: (E*p_factor, f, d).
    The launch derives its grid/blocks from this spec, so the
    ``repro.lint`` Pallas passes analyze exactly what runs."""
    g = _resolve_blocks(C, f, p_factor, n_minor_start, block_c, block_f)
    dt = dtype_name(dtype)
    blocks = (
        BlockUse("counts_full", (E,), "int32", "in", streamed=False,
                 control=True),
        BlockUse("counts_major", (E,), "int32", "in", streamed=False,
                 control=True),
        BlockUse("x", (1, g["block_c"], d), dt, "in"),
        BlockUse("w1", (1, d, g["block_f"]), dt, "in"),
        BlockUse("w3", (1, d, g["block_f"]), dt, "in"),
        BlockUse("w2", (1, g["block_f"], d), dt, "in"),
        BlockUse("out", (1, g["block_c"], d), "float32", "out"),
    )
    grid = (E, g["Cp"] // g["block_c"], g["n_f"])
    meta = dict(g, E=E, C=C, d=d, f=f, virtual_f=g["fp"] * p_factor)
    return KernelSpec("grouped_swiglu", grid, blocks, meta)


def fused_moe_pipeline_kernel_spec(T: int, d: int, f: int, E: int,
                                   n_pairs_padded: int, *,
                                   capacity: int, dtype=jnp.float32,
                                   p_factor: int = 1,
                                   n_minor_start: int | None = None,
                                   block_c: int = 128,
                                   block_f: int = 128,
                                   streamed: bool = True) -> KernelSpec:
    """Static launch description of ``fused_moe_pipeline_pallas``.

    ``streamed=True`` (production): the per-pair maps ride in SMEM via
    scalar prefetch, x and the f32 output live in ANY (HBM) memory, and
    VMEM holds only the revolving weight tiles plus the double-buffered
    (block_c, d) gather tiles and two f32 staging tiles — the working set
    is independent of T, so the 16 MB budget holds at prefill scale.

    ``streamed=False`` (resident): the original PR-6 layout with the whole
    (T, d) activation/output arrays VMEM-resident — kept as the
    bit-exactness oracle for the streamed kernel, the bench comparison
    point, and the lint negative test (it MUST blow the VMEM budget at
    prefill scale)."""
    g = _resolve_blocks(capacity, f, p_factor, n_minor_start,
                        block_c, block_f)
    dt = dtype_name(dtype)
    map_space = "smem" if streamed else "vmem"
    blocks = [
        BlockUse("group_offsets", (E,), "int32", "in", streamed=False,
                 control=True, space=map_space),
        BlockUse("counts_full", (E,), "int32", "in", streamed=False,
                 control=True, space=map_space),
        BlockUse("counts_major", (E,), "int32", "in", streamed=False,
                 control=True, space=map_space),
        BlockUse("tok_sorted", (n_pairs_padded,), "int32", "in",
                 streamed=False, control=True, space=map_space),
        BlockUse("combine_sorted", (n_pairs_padded,), "float32", "in",
                 streamed=False, control=True, space=map_space),
    ]
    if streamed:
        blocks += [
            BlockUse("x", (T, d), dt, "in", streamed=False,
                     space="any", dma_buffers=2),
            BlockUse("w1", (1, d, g["block_f"]), dt, "in"),
            BlockUse("w3", (1, d, g["block_f"]), dt, "in"),
            BlockUse("w2", (1, g["block_f"], d), dt, "in"),
            BlockUse("out", (T, d), "float32", "out", streamed=False,
                     space="any", dma_buffers=1),
            BlockUse("x_tiles", (2 * g["block_c"], d), dt, "scratch"),
            BlockUse("acc_scratch", (g["block_c"], d), "float32", "scratch"),
            BlockUse("out_stage", (g["block_c"], d), "float32", "scratch"),
        ]
    else:
        blocks += [
            BlockUse("x", (T, d), dt, "in", streamed=False),
            BlockUse("w1", (1, d, g["block_f"]), dt, "in"),
            BlockUse("w3", (1, d, g["block_f"]), dt, "in"),
            BlockUse("w2", (1, g["block_f"], d), dt, "in"),
            BlockUse("out", (T, d), "float32", "out", streamed=False),
            BlockUse("x_scratch", (g["block_c"], d), dt, "scratch"),
            BlockUse("acc_scratch", (g["block_c"], d), "float32", "scratch"),
        ]
    grid = (E, g["Cp"] // g["block_c"], g["n_f"])
    meta = dict(g, E=E, C=capacity, d=d, f=f, T=T, capacity=capacity,
                n_pairs_padded=n_pairs_padded, virtual_f=g["fp"] * p_factor,
                streamed=streamed)
    return KernelSpec("fused_moe_pipeline", grid, tuple(blocks), meta)


def _kernel(counts_full_ref, counts_major_ref,   # tiny (E,) control arrays
            x_ref, w1_ref, w3_ref, w2_ref, out_ref, *,
            block_c: int, block_f: int, n_minor_start: int):
    e = pl.program_id(0)
    c = pl.program_id(1)
    f = pl.program_id(2)

    cf = counts_full_ref[e]
    cm = counts_major_ref[e]
    row0 = c * block_c
    # a block is live iff any of its neurons is needed by any of its rows:
    # blocks containing major neurons serve cf+cm rows, minor-only blocks cf.
    has_major = f * block_f < n_minor_start
    live = row0 < jnp.where(has_major, cf + cm, cf)

    @pl.when(f == 0)
    def _init():
        out_ref[0] = jnp.zeros_like(out_ref[0])

    @pl.when(live)
    def _compute():
        x = x_ref[0]                                   # (block_c, d)
        w1 = w1_ref[0]                                 # (d, block_f)
        w3 = w3_ref[0]
        w2 = w2_ref[0]                                 # (block_f, d)
        h = jax.nn.silu(jnp.dot(x, w1, preferred_element_type=jnp.float32))
        h = h * jnp.dot(x, w3, preferred_element_type=jnp.float32)
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_c, 1), 0)
        # per-neuron validity handles f/2 not aligned to block_f exactly
        nids = f * block_f + jax.lax.broadcasted_iota(jnp.int32, (1, block_f), 1)
        valid_rows = jnp.where(nids < n_minor_start, cf + cm, cf)  # (1, bf)
        h = jnp.where(rows < valid_rows, h, 0.0)
        out_ref[0] += jnp.dot(h.astype(w2.dtype), w2,
                              preferred_element_type=jnp.float32
                              ).astype(out_ref.dtype)


def grouped_swiglu_pallas(x, w1, w3, w2, counts_full=None, counts_major=None,
                          *, p_factor: int = 1,
                          n_minor_start: int | None = None,
                          block_c: int = 128, block_f: int = 128,
                          interpret: bool = True):
    """See kernels.ref.grouped_swiglu_ref for semantics.

    x: (E, C, d); w1/w3: (E*p_factor, d, f); w2: (E*p_factor, f, d)
    -> (E, C, d).

    ``p_factor > 1`` — **fused sub-expert mode**: the weights are a
    partial-transformed layer (``core.partition``: sub-expert ``e*P + j``
    holds neuron slice j of original expert e). The grid's f axis walks the
    *virtual* concatenated width ``P*f`` of each original expert and the
    BlockSpec index map picks the owning sub-expert's slice — the fused
    full-width expert is reassembled by pure indexing, with zero weight
    copies. Sub-expert 0 is the reconstructed MAJOR half, so
    ``n_minor_start`` lands on the first sub-expert boundary and 2T-Drop's
    MAJOR-only rows (``counts_major``) skip every tile of sub-experts 1..P-1.

    ``n_minor_start`` — first neuron (virtual coordinate when fused) that
    belongs to the MINOR half. Defaults: ``f // 2`` at ``p_factor == 1``
    (pre-reconstructed full-width weights), the sub-expert width when fused.
    Pass the full width explicitly to disable the minor-half split (e.g. the
    S-ETP local buffers, where each group IS a single sub-expert and
    ``counts_major`` only tracks the row-mode ordering).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on TPU pass interpret=False.
    """
    E, C, d = x.shape
    Es, _, f = w1.shape
    assert Es == E * p_factor, (
        f"weights carry {Es} sub-experts; buffers have {E} groups x "
        f"p_factor {p_factor}")
    if counts_full is None:
        counts_full = jnp.full((E,), C, jnp.int32)
    if counts_major is None:
        counts_major = jnp.zeros((E,), jnp.int32)
    spec = grouped_swiglu_kernel_spec(
        E, C, d, f, dtype=x.dtype, p_factor=p_factor,
        n_minor_start=n_minor_start, block_c=block_c, block_f=block_f)
    g = spec.meta
    block_c, block_f = g["block_c"], g["block_f"]
    pc, pf = g["pad_c"], g["pad_f"]
    Cp, nf_sub = g["Cp"], g["nf_sub"]
    n_minor_start = g["n_minor_start"]
    grid = spec.grid
    # pad C / per-sub-expert f to block multiples (padded neuron columns are
    # zero in w1/w3 => silu(0)*0 == 0 contribution through zero w2 rows)
    if pc:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, 0)))
    if pf:
        w1 = jnp.pad(w1, ((0, 0), (0, 0), (0, pf)))
        w3 = jnp.pad(w3, ((0, 0), (0, 0), (0, pf)))
        w2 = jnp.pad(w2, ((0, 0), (0, pf), (0, 0)))

    kernel = functools.partial(
        _kernel, block_c=block_c, block_f=block_f,
        n_minor_start=n_minor_start)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((E,), lambda e, c, f: (0,)),          # counts_full
            pl.BlockSpec((E,), lambda e, c, f: (0,)),          # counts_major
            pl.BlockSpec((1, block_c, d), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, d, block_f),
                         lambda e, c, f: (e * p_factor + f // nf_sub, 0,
                                          f % nf_sub)),
            pl.BlockSpec((1, d, block_f),
                         lambda e, c, f: (e * p_factor + f // nf_sub, 0,
                                          f % nf_sub)),
            pl.BlockSpec((1, block_f, d),
                         lambda e, c, f: (e * p_factor + f // nf_sub,
                                          f % nf_sub, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d), lambda e, c, f: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, d), jnp.float32),
        interpret=interpret,
    )(counts_full.astype(jnp.int32), counts_major.astype(jnp.int32),
      x, w1, w3, w2)
    return out[:, :C].astype(x.dtype)


# ---------------------------------------------------------------------------
# Fused dispatch -> expert FFN -> combine pipeline (ROADMAP item 4)
# ---------------------------------------------------------------------------

def _fused_pipeline_kernel(offs_ref, cf_ref, cm_ref,      # (E,) control
                           tok_ref, wc_ref,               # (N_pad,) pair maps
                           x_ref, w1_ref, w3_ref, w2_ref, out_ref,
                           x_scr, acc_scr, *,
                           block_c: int, block_f: int, n_minor_start: int,
                           n_f: int):
    """One grid step = one (expert, row-block, neuron-block) tile.

    Instead of reading a pre-gathered (E, capacity, d) buffer, the kernel
    walks the sort permutation directly: the row block's sorted positions
    are ``offs[e] + row0 .. + block_c`` (contiguous by construction of
    ``DispatchPlan.perm``), ``tok_ref`` maps each sorted position to its
    source row of the flat (T, d) activation array, and ``wc_ref`` carries
    the pair's combine weight. Token rows are gathered once per row block
    (at f == 0) into VMEM scratch, the mode-ordered grouped SwiGLU runs
    with the same minor-half tile skipping as ``_kernel``, and the
    combine-weighted output rows are scatter-accumulated straight into the
    (T, d) output — no capacity buffer, no unpermute read-back.
    """
    e = pl.program_id(0)
    c = pl.program_id(1)
    f = pl.program_id(2)

    cf = cf_ref[e]
    cm = cm_ref[e]
    row0 = c * block_c
    any_rows = row0 < cf + cm                     # some row needs SOME tile
    has_major = f * block_f < n_minor_start
    live = row0 < jnp.where(has_major, cf + cm, cf)
    start = offs_ref[e] + row0

    @pl.when((e == 0) & (c == 0) & (f == 0))
    def _init_out():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    @pl.when((f == 0) & any_rows)
    def _gather():
        acc_scr[...] = jnp.zeros(acc_scr.shape, acc_scr.dtype)

        def body(j, _):
            tok = tok_ref[start + j]
            x_scr[pl.ds(j, 1), :] = x_ref[pl.ds(tok, 1), :]
            return 0
        jax.lax.fori_loop(0, block_c, body, 0)

    @pl.when(live)
    def _compute():
        x = x_scr[...]                                 # (block_c, d)
        w1 = w1_ref[0]                                 # (d, block_f)
        w3 = w3_ref[0]
        w2 = w2_ref[0]                                 # (block_f, d)
        h = jax.nn.silu(jnp.dot(x, w1, preferred_element_type=jnp.float32))
        h = h * jnp.dot(x, w3, preferred_element_type=jnp.float32)
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_c, 1), 0)
        nids = f * block_f + jax.lax.broadcasted_iota(jnp.int32, (1, block_f), 1)
        valid_rows = jnp.where(nids < n_minor_start, cf + cm, cf)  # (1, bf)
        h = jnp.where(rows < valid_rows, h, 0.0)
        acc_scr[...] += jnp.dot(h.astype(w2.dtype), w2,
                                preferred_element_type=jnp.float32)

    @pl.when((f == n_f - 1) & any_rows)
    def _scatter():
        def body(j, _):
            tok = tok_ref[start + j]
            w = jnp.where(row0 + j < cf + cm, wc_ref[start + j], 0.0)
            out_ref[pl.ds(tok, 1), :] += \
                w * acc_scr[pl.ds(j, 1), :].astype(out_ref.dtype)
            return 0
        jax.lax.fori_loop(0, block_c, body, 0)


def _fused_pipeline_streamed_kernel(
        offs_ref, cf_ref, cm_ref, tok_ref, wc_ref,   # scalar prefetch (SMEM)
        x_hbm, w1_ref, w3_ref, w2_ref, out_hbm,      # ANY + revolving VMEM
        x_tiles, acc_scr, stage, gather_sem, rw_sem, *,
        T: int, block_c: int, block_f: int, n_minor_start: int,
        n_f: int, n_c: int, n_blocks: int, E: int):
    """Streamed variant: VMEM holds only the revolving weight tiles plus
    ``x_tiles`` (2 x (block_c, d) — double-buffered gather destination),
    ``acc_scr`` and one f32 staging tile. The pair maps arrive through
    scalar prefetch (SMEM), x and out stay in ANY (HBM) memory and every
    touch is an explicit ``make_async_copy``:

      * gather — the row block of the NEXT (e, c) pair is DMA'd from
        x into the other half of ``x_tiles`` while the current block
        computes (classic double buffering keyed on the linear block
        index ``lin = e*n_c + c``; start and wait reconstruct identical
        per-row descriptors so the semaphore balances).
      * scatter — at each block's last f step, out rows are
        read-modify-written one row at a time through ``stage`` row 0
        (sequential per-row RMW keeps duplicate tokens exact).
      * init — grid step (0, 0, 0) zeroes out by DMA-ing a zeroed staging
        tile across the T rows before any scatter can read them.

    Arithmetic (accumulation order included) is identical to the resident
    kernel, so streamed == resident bit-exactly; only the residency and
    data movement differ.
    """
    e = pl.program_id(0)
    c = pl.program_id(1)
    f = pl.program_id(2)
    lin = e * n_c + c                             # linear (e, c) block index
    slot = jax.lax.rem(lin, 2)

    cf = cf_ref[e]
    cm = cm_ref[e]
    row0 = c * block_c
    any_rows = row0 < cf + cm                     # some row needs SOME tile
    has_major = f * block_f < n_minor_start
    live = row0 < jnp.where(has_major, cf + cm, cf)
    start = offs_ref[e] + row0

    def gather_dma(row, dst_slot, j):
        # one (1, d) row: x[tok] -> x_tiles[dst_slot*block_c + j]
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(row, 1), :],
            x_tiles.at[pl.ds(dst_slot * block_c + j, 1), :],
            gather_sem.at[dst_slot])

    def start_block_gather(blk, dst_slot):
        blk_start = offs_ref[blk // n_c] + (blk % n_c) * block_c

        def body(j, _):
            gather_dma(tok_ref[blk_start + j], dst_slot, j).start()
            return 0
        jax.lax.fori_loop(0, block_c, body, 0)

    def wait_block_gather(blk, dst_slot):
        blk_start = offs_ref[blk // n_c] + (blk % n_c) * block_c

        def body(j, _):
            gather_dma(tok_ref[blk_start + j], dst_slot, j).wait()
            return 0
        jax.lax.fori_loop(0, block_c, body, 0)

    @pl.when((lin == 0) & (f == 0))
    def _init_out():
        # Zero the (T, d) HBM accumulator by staging a zeroed tile; the
        # in-step waits order every zero write before the first scatter.
        stage[...] = jnp.zeros(stage.shape, stage.dtype)

        if T >= block_c:                 # static: loop body traces eagerly
            def zbody(k, _):
                cp = pltpu.make_async_copy(
                    stage.at[:, :],
                    out_hbm.at[pl.ds(k * block_c, block_c), :], rw_sem)
                cp.start()
                cp.wait()
                return 0
            jax.lax.fori_loop(0, T // block_c, zbody, 0)
        tail = T % block_c
        if tail:
            cp = pltpu.make_async_copy(
                stage.at[pl.ds(0, tail), :],
                out_hbm.at[pl.ds(T - tail, tail), :], rw_sem)
            cp.start()
            cp.wait()

    @pl.when(f == 0)
    def _dma_phase():
        # warm-up: the very first live block gathers for itself
        @pl.when((lin == 0) & any_rows)
        def _():
            start_block_gather(lin, slot)

        @pl.when(any_rows)
        def _():
            wait_block_gather(lin, slot)
            acc_scr[...] = jnp.zeros(acc_scr.shape, acc_scr.dtype)

        # steady state: prefetch the NEXT block's rows into the other slot
        nxt = lin + 1
        e1 = jnp.minimum(nxt // n_c, E - 1)       # clamp: nxt may be past end
        nxt_any = (nxt % n_c) * block_c < cf_ref[e1] + cm_ref[e1]

        @pl.when((nxt < n_blocks) & nxt_any)
        def _():
            start_block_gather(nxt, 1 - slot)

    @pl.when(live)
    def _compute():
        x = x_tiles[pl.ds(slot * block_c, block_c), :]   # (block_c, d)
        w1 = w1_ref[0]                                   # (d, block_f)
        w3 = w3_ref[0]
        w2 = w2_ref[0]                                   # (block_f, d)
        h = jax.nn.silu(jnp.dot(x, w1, preferred_element_type=jnp.float32))
        h = h * jnp.dot(x, w3, preferred_element_type=jnp.float32)
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_c, 1), 0)
        nids = f * block_f + jax.lax.broadcasted_iota(jnp.int32, (1, block_f), 1)
        valid_rows = jnp.where(nids < n_minor_start, cf + cm, cf)  # (1, bf)
        h = jnp.where(rows < valid_rows, h, 0.0)
        acc_scr[...] += jnp.dot(h.astype(w2.dtype), w2,
                                preferred_element_type=jnp.float32)

    @pl.when((f == n_f - 1) & any_rows)
    def _scatter():
        # sequential per-row RMW through stage row 0: duplicate tokens in
        # one block stay exact because each row's write completes before
        # the next row's read starts.
        def body(j, _):
            tok = tok_ref[start + j]
            w = jnp.where(row0 + j < cf + cm, wc_ref[start + j], 0.0)
            rd = pltpu.make_async_copy(out_hbm.at[pl.ds(tok, 1), :],
                                       stage.at[pl.ds(0, 1), :], rw_sem)
            rd.start()
            rd.wait()
            stage[pl.ds(0, 1), :] = (stage[pl.ds(0, 1), :] +
                                     w * acc_scr[pl.ds(j, 1), :])
            wr = pltpu.make_async_copy(stage.at[pl.ds(0, 1), :],
                                       out_hbm.at[pl.ds(tok, 1), :], rw_sem)
            wr.start()
            wr.wait()
            return 0
        jax.lax.fori_loop(0, block_c, body, 0)


def fused_moe_pipeline_pallas(x, w1, w3, w2, group_offsets, counts_full,
                              counts_major, tok_sorted, combine_sorted, *,
                              capacity: int, p_factor: int = 1,
                              n_minor_start: int | None = None,
                              block_c: int = 128, block_f: int = 128,
                              streamed: bool = True,
                              interpret: bool = True):
    """Fused dispatch -> grouped SwiGLU -> weighted combine (one kernel).

    x: (T, d) flat token activations; w1/w3: (E*p_factor, d, f);
    w2: (E*p_factor, f, d) -> (T, d).

    ``group_offsets``/``counts_full``/``counts_major``: (E,) from a
    ``DispatchPlan`` (counts already clamped to ``capacity``, see
    ``DispatchPlan.kernel_counts``). ``tok_sorted``: (N',) source row of
    the flat activation array per SORTED pair position (``plan.perm``
    divided by the pair fan-out); ``combine_sorted``: (N',) combine weight
    (zero for dropped pairs) in the same order. Both must be padded with
    ``block_c`` trailing entries (token 0, weight 0) so the final row
    block's slice stays in range — ``core.dispatch.sorted_pair_arrays``
    builds them.

    Semantics match the three-step oracle
    ``gather_rows -> grouped_swiglu -> unpermute + combine`` to fp
    tolerance: the same rows are computed (capacity clamping included) and
    each kept pair contributes ``combine * f_e(x_tok)`` to its token's
    output row; only the float accumulation order differs.

    ``p_factor`` / ``n_minor_start`` follow ``grouped_swiglu_pallas``: the
    f axis walks the virtual concatenated width of partitioned sub-expert
    weights and MAJOR-only rows skip every minor-half tile.

    ``streamed=True`` (default, production): pair maps ride in SMEM via
    ``pltpu.PrefetchScalarGridSpec`` scalar prefetch, x/out live in ANY
    (HBM) memory, and every row touch is an explicit double-buffered
    ``pltpu.make_async_copy`` — the VMEM working set is independent of T.
    ``streamed=False`` keeps the original whole-array-resident layout
    (the streamed kernel's bit-exactness oracle and the lint negative
    test). Both produce identical bits; ``interpret=True`` (this
    container) validates the block/skip/DMA logic on CPU.
    """
    T, d = x.shape
    Es, _, f = w1.shape
    E = group_offsets.shape[0]
    assert Es == E * p_factor, (
        f"weights carry {Es} sub-experts; plan has {E} groups x "
        f"p_factor {p_factor}")
    assert capacity >= 1
    assert tok_sorted.shape == combine_sorted.shape
    Np = tok_sorted.shape[0]
    spec = fused_moe_pipeline_kernel_spec(
        T, d, f, E, Np, capacity=capacity, dtype=x.dtype,
        p_factor=p_factor, n_minor_start=n_minor_start,
        block_c=block_c, block_f=block_f, streamed=streamed)
    g = spec.meta
    block_c, block_f = g["block_c"], g["block_f"]
    pf, nf_sub, n_f = g["pad_f"], g["nf_sub"], g["n_f"]
    n_minor_start = g["n_minor_start"]
    grid = spec.grid
    if pf:
        w1 = jnp.pad(w1, ((0, 0), (0, 0), (0, pf)))
        w3 = jnp.pad(w3, ((0, 0), (0, 0), (0, pf)))
        w2 = jnp.pad(w2, ((0, 0), (0, pf), (0, 0)))

    operands = (group_offsets.astype(jnp.int32),
                counts_full.astype(jnp.int32),
                counts_major.astype(jnp.int32),
                tok_sorted.astype(jnp.int32),
                combine_sorted.astype(jnp.float32), x, w1, w3, w2)

    if streamed:
        n_c = grid[1]
        kernel = functools.partial(
            _fused_pipeline_streamed_kernel, T=T, block_c=block_c,
            block_f=block_f, n_minor_start=n_minor_start, n_f=n_f,
            n_c=n_c, n_blocks=E * n_c, E=E)

        # index maps receive the 5 scalar-prefetch refs as trailing args
        def w13_map(e, c, f, *_refs):
            return (e * p_factor + f // nf_sub, 0, f % nf_sub)

        def w2_map(e, c, f, *_refs):
            return (e * p_factor + f // nf_sub, f % nf_sub, 0)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),        # x (HBM)
                pl.BlockSpec((1, d, block_f), w13_map),
                pl.BlockSpec((1, d, block_f), w13_map),
                pl.BlockSpec((1, block_f, d), w2_map),
            ],
            out_specs=pl.BlockSpec(memory_space=pltpu.ANY),  # out (HBM)
            scratch_shapes=[
                pltpu.VMEM((2 * block_c, d), x.dtype),       # gather tiles
                pltpu.VMEM((block_c, d), jnp.float32),       # output accum
                pltpu.VMEM((block_c, d), jnp.float32),       # zero/RMW stage
                pltpu.SemaphoreType.DMA((2,)),               # per-slot gather
                pltpu.SemaphoreType.DMA,                     # zero + RMW
            ],
        )
        out = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((T, d), jnp.float32),
            interpret=interpret,
        )(*operands)
        return out.astype(x.dtype)

    kernel = functools.partial(
        _fused_pipeline_kernel, block_c=block_c, block_f=block_f,
        n_minor_start=n_minor_start, n_f=n_f)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((E,), lambda e, c, f: (0,)),        # group_offsets
            pl.BlockSpec((E,), lambda e, c, f: (0,)),        # counts_full
            pl.BlockSpec((E,), lambda e, c, f: (0,)),        # counts_major
            pl.BlockSpec((Np,), lambda e, c, f: (0,)),       # tok_sorted
            pl.BlockSpec((Np,), lambda e, c, f: (0,)),       # combine_sorted
            pl.BlockSpec((T, d), lambda e, c, f: (0, 0)),    # x (whole)
            pl.BlockSpec((1, d, block_f),
                         lambda e, c, f: (e * p_factor + f // nf_sub, 0,
                                          f % nf_sub)),
            pl.BlockSpec((1, d, block_f),
                         lambda e, c, f: (e * p_factor + f // nf_sub, 0,
                                          f % nf_sub)),
            pl.BlockSpec((1, block_f, d),
                         lambda e, c, f: (e * p_factor + f // nf_sub,
                                          f % nf_sub, 0)),
        ],
        out_specs=pl.BlockSpec((T, d), lambda e, c, f: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_c, d), x.dtype),               # gathered rows
            pltpu.VMEM((block_c, d), jnp.float32),           # output accum
        ],
        interpret=interpret,
    )(*operands)
    return out.astype(x.dtype)
