"""Pallas TPU kernel: grouped SwiGLU expert FFN with dual-sparse block
skipping (the TPU adaptation of the paper's §4.2 Triton kernel).

Design (see DESIGN.md §3):
  * tokens are pre-sorted per expert buffer: FULL-mode rows first, then
    MAJOR-only rows, then padding. Neurons are pre-reconstructed so the
    MAJOR half occupies d_ff slots [0, f/2).
  * grid = (E, C/block_c, f/block_f); the f axis is innermost and
    accumulates into the (block_c, d) output tile resident in VMEM.
  * a (token-block, neuron-block) pair is SKIPPED with ``pl.when`` whenever
    no row of the block needs that neuron half:
        neuron block in MINOR half -> valid rows = counts_full[e]
        neuron block in MAJOR half -> valid rows = counts_full[e]+counts_major[e]
    so 2T-Drop's computation dropping becomes whole MXU tiles never issued —
    the tensor-granular saving the paper argues is what real hardware can
    actually cash in (vs. fine-grained sparsity).
  * within a partially-valid block, rows are masked by an iota compare
    (VPU-cheap) for exactness.

Block shapes default to (128, 128) — MXU-aligned; d (the contraction /
output width) stays whole per tile so each grid step is one
(block_c × d) @ (d × block_f) MXU matmul pair + one (block_c × block_f) @
(block_f × d) accumulation.

VMEM working set per step ≈ (block_c·d + 2·d·block_f + block_f·d +
block_c·d) · bytes — e.g. d=2048, blocks 128/128, bf16: ≈ 2.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(counts_full_ref, counts_major_ref,   # tiny (E,) control arrays
            x_ref, w1_ref, w3_ref, w2_ref, out_ref, *,
            block_c: int, block_f: int, n_minor_start: int):
    e = pl.program_id(0)
    c = pl.program_id(1)
    f = pl.program_id(2)

    cf = counts_full_ref[e]
    cm = counts_major_ref[e]
    row0 = c * block_c
    # a block is live iff any of its neurons is needed by any of its rows:
    # blocks containing major neurons serve cf+cm rows, minor-only blocks cf.
    has_major = f * block_f < n_minor_start
    live = row0 < jnp.where(has_major, cf + cm, cf)

    @pl.when(f == 0)
    def _init():
        out_ref[0] = jnp.zeros_like(out_ref[0])

    @pl.when(live)
    def _compute():
        x = x_ref[0]                                   # (block_c, d)
        w1 = w1_ref[0]                                 # (d, block_f)
        w3 = w3_ref[0]
        w2 = w2_ref[0]                                 # (block_f, d)
        h = jax.nn.silu(jnp.dot(x, w1, preferred_element_type=jnp.float32))
        h = h * jnp.dot(x, w3, preferred_element_type=jnp.float32)
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_c, 1), 0)
        # per-neuron validity handles f/2 not aligned to block_f exactly
        nids = f * block_f + jax.lax.broadcasted_iota(jnp.int32, (1, block_f), 1)
        valid_rows = jnp.where(nids < n_minor_start, cf + cm, cf)  # (1, bf)
        h = jnp.where(rows < valid_rows, h, 0.0)
        out_ref[0] += jnp.dot(h.astype(w2.dtype), w2,
                              preferred_element_type=jnp.float32
                              ).astype(out_ref.dtype)


def grouped_swiglu_pallas(x, w1, w3, w2, counts_full=None, counts_major=None,
                          *, p_factor: int = 1,
                          n_minor_start: int | None = None,
                          block_c: int = 128, block_f: int = 128,
                          interpret: bool = True):
    """See kernels.ref.grouped_swiglu_ref for semantics.

    x: (E, C, d); w1/w3: (E*p_factor, d, f); w2: (E*p_factor, f, d)
    -> (E, C, d).

    ``p_factor > 1`` — **fused sub-expert mode**: the weights are a
    partial-transformed layer (``core.partition``: sub-expert ``e*P + j``
    holds neuron slice j of original expert e). The grid's f axis walks the
    *virtual* concatenated width ``P*f`` of each original expert and the
    BlockSpec index map picks the owning sub-expert's slice — the fused
    full-width expert is reassembled by pure indexing, with zero weight
    copies. Sub-expert 0 is the reconstructed MAJOR half, so
    ``n_minor_start`` lands on the first sub-expert boundary and 2T-Drop's
    MAJOR-only rows (``counts_major``) skip every tile of sub-experts 1..P-1.

    ``n_minor_start`` — first neuron (virtual coordinate when fused) that
    belongs to the MINOR half. Defaults: ``f // 2`` at ``p_factor == 1``
    (pre-reconstructed full-width weights), the sub-expert width when fused.
    Pass the full width explicitly to disable the minor-half split (e.g. the
    S-ETP local buffers, where each group IS a single sub-expert and
    ``counts_major`` only tracks the row-mode ordering).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on TPU pass interpret=False.
    """
    E, C, d = x.shape
    Es, _, f = w1.shape
    assert Es == E * p_factor, (
        f"weights carry {Es} sub-experts; buffers have {E} groups x "
        f"p_factor {p_factor}")
    if counts_full is None:
        counts_full = jnp.full((E,), C, jnp.int32)
    if counts_major is None:
        counts_major = jnp.zeros((E,), jnp.int32)
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    # pad C / per-sub-expert f to block multiples (padded neuron columns are
    # zero in w1/w3 => silu(0)*0 == 0 contribution through zero w2 rows)
    pc, pf = (-C) % block_c, (-f) % block_f
    if pc:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, 0)))
    if pf:
        w1 = jnp.pad(w1, ((0, 0), (0, 0), (0, pf)))
        w3 = jnp.pad(w3, ((0, 0), (0, 0), (0, pf)))
        w2 = jnp.pad(w2, ((0, 0), (0, pf), (0, 0)))
    Cp, fp = C + pc, f + pf
    nf_sub = fp // block_f              # f-blocks per sub-expert
    grid = (E, Cp // block_c, p_factor * nf_sub)

    if n_minor_start is None:
        if p_factor > 1:
            n_minor_start = fp          # everything past sub-expert 0
        else:
            n_minor_start = f // 2 if f % 2 == 0 else f

    kernel = functools.partial(
        _kernel, block_c=block_c, block_f=block_f,
        n_minor_start=n_minor_start)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((E,), lambda e, c, f: (0,)),          # counts_full
            pl.BlockSpec((E,), lambda e, c, f: (0,)),          # counts_major
            pl.BlockSpec((1, block_c, d), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, d, block_f),
                         lambda e, c, f: (e * p_factor + f // nf_sub, 0,
                                          f % nf_sub)),
            pl.BlockSpec((1, d, block_f),
                         lambda e, c, f: (e * p_factor + f // nf_sub, 0,
                                          f % nf_sub)),
            pl.BlockSpec((1, block_f, d),
                         lambda e, c, f: (e * p_factor + f // nf_sub,
                                          f % nf_sub, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d), lambda e, c, f: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, d), jnp.float32),
        interpret=interpret,
    )(counts_full.astype(jnp.int32), counts_major.astype(jnp.int32),
      x, w1, w3, w2)
    return out[:, :C].astype(x.dtype)
