"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in ``interpret=True`` (the kernel body
executes in Python, validating block logic exactly); on a real TPU backend
they lower natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dualsparse_ffn import grouped_swiglu_pallas
from . import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("p_factor", "n_minor_start",
                                             "block_c", "block_f"))
def grouped_swiglu(x, w1, w3, w2, counts_full=None, counts_major=None,
                   p_factor: int = 1, n_minor_start=None,
                   block_c: int = 128, block_f: int = 128):
    """Grouped SwiGLU expert FFN (optionally with 2T-Drop counts).

    x: (E, C, d) -> (E, C, d). ``p_factor > 1`` fuses partial-transformed
    sub-expert weights back into full-width experts by BlockSpec indexing so
    MAJOR-only rows skip the minor sub-experts' tiles; ``n_minor_start``
    overrides the minor-half boundary (pass the full width to disable the
    split). See kernels.ref / kernels.dualsparse_ffn for exact semantics."""
    return grouped_swiglu_pallas(
        x, w1, w3, w2, counts_full, counts_major,
        p_factor=p_factor, n_minor_start=n_minor_start,
        block_c=block_c, block_f=block_f, interpret=not _on_tpu())


grouped_swiglu_ref = ref.grouped_swiglu_ref
