"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in ``interpret=True`` (the kernel body
executes in Python, validating block logic exactly); on a real TPU backend
they lower natively.
"""
from __future__ import annotations

import functools

import jax

from .dualsparse_ffn import (fused_moe_pipeline_kernel_spec,
                             fused_moe_pipeline_pallas,
                             grouped_swiglu_kernel_spec,
                             grouped_swiglu_pallas)
from . import ref

__all__ = ["fused_moe_pipeline", "grouped_swiglu", "grouped_swiglu_ref",
           "fused_moe_pipeline_kernel_spec", "grouped_swiglu_kernel_spec",
           "fused_moe_pipeline_pallas", "grouped_swiglu_pallas"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("capacity", "p_factor",
                                             "n_minor_start", "block_c",
                                             "block_f", "streamed"))
def fused_moe_pipeline(x, w1, w3, w2, group_offsets, counts_full,
                       counts_major, tok_sorted, combine_sorted,
                       capacity: int, p_factor: int = 1, n_minor_start=None,
                       block_c: int = 128, block_f: int = 128,
                       streamed: bool = True):
    """Fused dispatch -> grouped SwiGLU -> weighted combine in ONE Pallas
    kernel: gathers token rows from the flat (T, d) activation array
    through the sort permutation, runs the mode-ordered dual-sparse FFN
    (minor-half MXU tiles of MAJOR-only rows skipped), and
    scatter-accumulates combine-weighted outputs per token — no
    (E, capacity, d) HBM buffer, no unpermute read-back.

    ``streamed=True`` (default): pair maps in scalar-prefetch SMEM, x/out
    in ANY (HBM) memory with explicit double-buffered DMA, so the VMEM
    working set is independent of T (prefill-safe). ``streamed=False``
    keeps the whole-array-resident PR-6 layout (bit-identical output).
    See kernels.dualsparse_ffn.fused_moe_pipeline_pallas for the
    contract; ``core.dispatch.sorted_pair_arrays`` builds the pair maps."""
    return fused_moe_pipeline_pallas(
        x, w1, w3, w2, group_offsets, counts_full, counts_major,
        tok_sorted, combine_sorted, capacity=capacity, p_factor=p_factor,
        n_minor_start=n_minor_start, block_c=block_c, block_f=block_f,
        streamed=streamed, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("p_factor", "n_minor_start",
                                             "block_c", "block_f"))
def grouped_swiglu(x, w1, w3, w2, counts_full=None, counts_major=None,
                   p_factor: int = 1, n_minor_start=None,
                   block_c: int = 128, block_f: int = 128):
    """Grouped SwiGLU expert FFN (optionally with 2T-Drop counts).

    x: (E, C, d) -> (E, C, d). ``p_factor > 1`` fuses partial-transformed
    sub-expert weights back into full-width experts by BlockSpec indexing so
    MAJOR-only rows skip the minor sub-experts' tiles; ``n_minor_start``
    overrides the minor-half boundary (pass the full width to disable the
    split). See kernels.ref / kernels.dualsparse_ffn for exact semantics."""
    return grouped_swiglu_pallas(
        x, w1, w3, w2, counts_full, counts_major,
        p_factor=p_factor, n_minor_start=n_minor_start,
        block_c=block_c, block_f=block_f, interpret=not _on_tpu())


grouped_swiglu_ref = ref.grouped_swiglu_ref
