"""Pallas TPU kernel: Mamba2/SSD intra-chunk compute.

The chunked SSD algorithm (models/mamba2.py) splits into (a) per-chunk
quadratic work — the hot spot: three (Q×N)/(Q×Q)/(Q×P) matmuls per chunk —
and (b) a cheap log-depth inter-chunk recurrence. This kernel executes (a)
on the MXU with one grid step per (batch·head, chunk):

    dA        = dt * a                      (VPU)
    L[i,j]    = exp(segsum(dA))  (i>=j)     (VPU: cumsum + mask)
    scores    = C @ B^T                     (MXU, Q×N × N×Q)
    y_intra   = (scores ∘ L ∘ dt) @ x       (MXU, Q×Q × Q×P)
    states    = (B ∘ dt ∘ decay_to_end)^T @ x   (MXU, N×Q × Q×P)
    decay     = exp(sum dA)                 (scalar per chunk)

Outputs feed the associative scan + inter-chunk term in plain JAX.
Validated in interpret mode against the pure-jnp path (tests/test_kernels.py).

Block sizes: the whole chunk (Q ≤ 256) is one block — Q, P, N are all
128-aligned for the production configs (Q=128/256, P=64, N=64/128), and the
VMEM working set is x(Q·P) + B,C(Q·N) + L(Q·Q) + out(Q·P) ≈ 0.6 MB at
Q=256, P=64, N=128 in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(a_ref, x_ref, dt_ref, b_ref, c_ref,
            y_ref, st_ref, dec_ref, *, chunk: int):
    x = x_ref[0, 0]                    # (Q, P)
    dt = dt_ref[0, 0]                  # (Q,)
    bm = b_ref[0, 0]                   # (Q, N)
    cm = c_ref[0, 0]                   # (Q, N)
    a = a_ref[0]                       # scalar A (<0) for this head

    dA = dt * a                        # (Q,)
    cum = jnp.cumsum(dA)               # (Q,)
    # segsum: cum[i] - cum[j], lower-triangular (incl. diagonal)
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    scores = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)  # (Q,Q)
    M = scores * L * dt[None, :]
    y_ref[0, 0] = jnp.dot(M, x, preferred_element_type=jnp.float32)

    decay_to_end = jnp.exp(cum[-1] - cum)                            # (Q,)
    w = bm * (dt * decay_to_end)[:, None]                            # (Q,N)
    st_ref[0, 0] = jnp.dot(w.T, x, preferred_element_type=jnp.float32)
    dec_ref[0, 0] = jnp.exp(cum[-1])


def ssd_chunk_pallas(x, dt, a, bm, cm, *, interpret: bool = True):
    """Intra-chunk SSD.

    x: (BH, nc, Q, P); dt: (BH, nc, Q); a: (BH,); bm, cm: (BH, nc, Q, N).
    Returns (y_intra (BH,nc,Q,P), states (BH,nc,N,P), decay (BH,nc)).
    """
    BH, nc, Q, P = x.shape
    N = bm.shape[-1]
    kernel = functools.partial(_kernel, chunk=Q)
    y, st, dec = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda b, c: (b,)),                # a
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, N, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc), jnp.float32),
        ],
        interpret=interpret,
    )(a, x, dt, bm, cm)
    return y, st, dec


def ssd_chunk_ref(x, dt, a, bm, cm):
    """Pure-jnp oracle with identical signature."""
    dA = dt * a[:, None, None]                       # (BH, nc, Q)
    cum = jnp.cumsum(dA, axis=-1)
    seg = cum[..., :, None] - cum[..., None, :]
    Q = x.shape[2]
    mask = np.tril(np.ones((Q, Q), bool))
    L = jnp.where(mask, jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqn,bckn->bcqk", cm, bm)
    M = scores * L * dt[..., None, :]
    y = jnp.einsum("bcqk,bckp->bcqp", M, x)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)
    w = bm * (dt * decay_to_end)[..., None]
    st = jnp.einsum("bcqn,bcqp->bcnp", w, x)
    return y, st, jnp.exp(cum[..., -1])
