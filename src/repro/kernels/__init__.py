# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Public kernel entry points.

The jit'd wrappers (``fused_moe_pipeline``, ``grouped_swiglu``) are the
production surface; the ``*_pallas`` launches accept ``interpret=`` for
tests; the ``*_kernel_spec`` builders return the static ``KernelSpec``
each launch derives its geometry from — the object ``repro.lint``'s
Pallas passes analyze. Downstream code (and the lint registry) imports
from this package, not the submodules.
"""
from .specs import BlockUse, KernelSpec
from .dualsparse_ffn import (fused_moe_pipeline_kernel_spec,
                             fused_moe_pipeline_pallas,
                             grouped_swiglu_kernel_spec,
                             grouped_swiglu_pallas)
from .ops import fused_moe_pipeline, grouped_swiglu, grouped_swiglu_ref

__all__ = [
    "BlockUse", "KernelSpec",
    "fused_moe_pipeline", "grouped_swiglu", "grouped_swiglu_ref",
    "fused_moe_pipeline_kernel_spec", "grouped_swiglu_kernel_spec",
    "fused_moe_pipeline_pallas", "grouped_swiglu_pallas",
]
