"""Introspectable Pallas launch metadata.

Every Pallas kernel in this package derives its launch geometry (grid,
block shapes, padding, scratch allocation) from a ``KernelSpec`` built by a
pure function of the logical shapes — the SAME spec object the static
analyzers in ``repro.lint.pallas_passes`` consume. Because the kernel
launch and the lint read one source of truth, the VMEM-footprint /
MXU-alignment / grid-coverage checks can never drift from what actually
runs, and they run on CPU with no TPU and no tracing at all.

Residency model: each ``BlockUse`` carries a memory ``space``:

- ``"vmem"`` — lives in vector memory. Streamed non-scratch blocks are
  double-buffered by the Pallas pipeline (x2); resident blocks and
  scratch count once.
- ``"smem"`` — scalar memory (control maps fed through
  ``PrefetchScalarGridSpec``); counted against the SMEM budget only.
- ``"any"``  — compiler-placed (HBM at these sizes); never touches the
  VMEM budget. The kernel reaches it with explicit DMA, and
  ``dma_buffers`` records how many VMEM staging copies of one block the
  kernel keeps in flight (2 = double-buffered). Staging tiles appear as
  their own scratch blocks, so ``dma_buffers`` is audit metadata — the
  lint DMA pass checks that streamed-in ``any`` blocks are at least
  double-buffered.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class BlockUse:
    """One operand/result/scratch block of a kernel grid step."""
    name: str
    shape: Tuple[int, ...]          # per-grid-step block shape
    dtype: str                      # numpy dtype name, e.g. "float32"
    kind: str                       # "in" | "out" | "scratch"
    streamed: bool = True           # block revolves per grid step (the
    #                                 pipeline double-buffers it); False =
    #                                 whole-array resident for the launch
    control: bool = False           # scalar control data (counts, offsets,
    #                                 pair maps) — exempt from MXU tiling
    space: str = "vmem"             # "vmem" | "smem" | "any"
    dma_buffers: int = 0            # for space="any": VMEM staging copies
    #                                 the kernel keeps in flight (2 =
    #                                 double-buffered explicit DMA)

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * \
            np.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Static launch description of one ``pl.pallas_call``.

    ``meta`` carries the resolved geometry (padded dims, minor-half
    boundary, logical shapes) the analyzers cross-check; keys are
    kernel-specific but always include the logical dims used to build the
    spec.
    """
    name: str
    grid: Tuple[int, ...]
    blocks: Tuple[BlockUse, ...]
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def vmem_bytes(self) -> int:
        """Static VMEM working-set estimate for one grid step: streamed
        vmem blocks are double-buffered by the Pallas pipeline (x2),
        resident blocks and scratch are allocated once. SMEM- and
        ANY-space blocks do not occupy VMEM (their staging tiles are
        separate scratch entries)."""
        total = 0
        for b in self.blocks:
            if b.space != "vmem":
                continue
            mult = 2 if (b.streamed and b.kind != "scratch") else 1
            total += mult * b.nbytes
        return total

    def smem_bytes(self) -> int:
        """Static SMEM working set: scalar-prefetch maps and any other
        SMEM-space blocks, allocated once for the launch."""
        return sum(b.nbytes for b in self.blocks if b.space == "smem")

    def blocks_of_kind(self, kind: str) -> Tuple[BlockUse, ...]:
        return tuple(b for b in self.blocks if b.kind == kind)

    def blocks_of_space(self, space: str) -> Tuple[BlockUse, ...]:
        return tuple(b for b in self.blocks if b.space == space)


def dtype_name(dtype) -> str:
    return np.dtype(dtype).name
