"""Introspectable Pallas launch metadata.

Every Pallas kernel in this package derives its launch geometry (grid,
block shapes, padding, scratch allocation) from a ``KernelSpec`` built by a
pure function of the logical shapes — the SAME spec object the static
analyzers in ``repro.lint.pallas_passes`` consume. Because the kernel
launch and the lint read one source of truth, the VMEM-footprint /
MXU-alignment / grid-coverage checks can never drift from what actually
runs, and they run on CPU with no TPU and no tracing at all.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class BlockUse:
    """One operand/result/scratch block of a kernel grid step."""
    name: str
    shape: Tuple[int, ...]          # per-grid-step block shape
    dtype: str                      # numpy dtype name, e.g. "float32"
    kind: str                       # "in" | "out" | "scratch"
    streamed: bool = True           # block revolves per grid step (the
    #                                 pipeline double-buffers it); False =
    #                                 whole-array resident for the launch
    control: bool = False           # scalar control data (counts, offsets,
    #                                 pair maps) — exempt from MXU tiling

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * \
            np.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Static launch description of one ``pl.pallas_call``.

    ``meta`` carries the resolved geometry (padded dims, minor-half
    boundary, logical shapes) the analyzers cross-check; keys are
    kernel-specific but always include the logical dims used to build the
    spec.
    """
    name: str
    grid: Tuple[int, ...]
    blocks: Tuple[BlockUse, ...]
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def vmem_bytes(self) -> int:
        """Static VMEM working-set estimate for one grid step: streamed
        blocks are double-buffered by the Pallas pipeline (x2), resident
        blocks and scratch are allocated once."""
        total = 0
        for b in self.blocks:
            mult = 2 if (b.streamed and b.kind != "scratch") else 1
            total += mult * b.nbytes
        return total

    def blocks_of_kind(self, kind: str) -> Tuple[BlockUse, ...]:
        return tuple(b for b in self.blocks if b.kind == kind)


def dtype_name(dtype) -> str:
    return np.dtype(dtype).name
