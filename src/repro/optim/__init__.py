from .adamw import adamw, cosine_schedule, clip_by_global_norm  # noqa: F401
