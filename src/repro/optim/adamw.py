"""AdamW + cosine schedule + global-norm clipping, in plain JAX.

Interface mirrors optax (init/update returning updates to be added) so the
training loop stays framework-agnostic; implemented from scratch since only
jax/numpy ship in this container.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def cosine_schedule(peak_lr: float, total_steps: int, warmup: int = 100,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(np.pi * prog))
        return jnp.where(step < warmup, warm, peak_lr * cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.01,
          max_grad_norm: float = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                 params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=z(), nu=z())

    def update(grads, state: AdamWState, params):
        if max_grad_norm:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        b1t = 1 - b1 ** step.astype(jnp.float32)
        b2t = 1 - b2 ** step.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        lr_t = lr_fn(step)

        def upd(m, v, p):
            mhat = m / b1t
            vhat = v / b2t
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)
