"""Unified serving request API (paper §4).

Every engine — synchronized-batch, continuous-batching, paged — speaks the
same request lifecycle:

    uid = engine.submit(prompt, gen)     # enqueue (validated, never blocks)
    while engine.step(): ...             # advance one scheduler iteration
    results = engine.drain()             # run to completion, collect Results

``Request`` is the canonical unit of work (prompt tokens + per-request
``GenerationConfig`` + optional arrival time for replayed traces); ``Result``
is the canonical outcome. ``Engine`` is the structural protocol benchmarks
and launchers program against; ``EngineBase`` supplies the shared lifecycle
(uid allocation, result bookkeeping, ``run``/``drain``/``generate``/
``generate_timed``) so concrete engines only implement admission + ``step``.

Scheduling semantics stay engine-specific: the synchronized engine's
``step()`` serves one convoy batch to completion, the continuous/paged
engines' ``step()`` is one admit+decode iteration. ``generate_timed`` drives
either through the same loop via two hooks: ``_has_work()`` (anything queued
or in flight) and ``_ready()`` (worth stepping now, e.g. the synchronized
engine waits for a full convoy until the trace is exhausted).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import (Deque, Dict, List, Optional, Protocol, Sequence, Tuple,
                    runtime_checkable)

import numpy as np

from ..core.policy import SparsityPolicy
from ..obs import MetricsSnapshot, SpanTracer


@dataclasses.dataclass
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 => greedy
    eos_token: int = -1               # -1 => never stop early
    seed: int = 0
    # per-request sparsity-policy override. Engines require the SAME policy
    # family (pytree structure) as their base policy — only threshold
    # *values* may differ, so co-batched requests decode in one jitted step
    # with per-slot thresholds and nothing retraces.
    policy: Optional[SparsityPolicy] = None


@dataclasses.dataclass
class Request:
    """One unit of serving work: prompt tokens, generation settings, and an
    optional arrival time (seconds on the engine clock) for trace replay."""
    prompt: np.ndarray
    gen: GenerationConfig = dataclasses.field(default_factory=GenerationConfig)
    arrival: float = 0.0


@dataclasses.dataclass
class Result:
    uid: int
    tokens: List[int]
    prefill_s: float = 0.0
    decode_s: float = 0.0
    submitted_s: float = 0.0          # arrival time (engine clock)
    finished_s: float = 0.0           # completion time (engine clock)
    first_token_s: Optional[float] = None   # first token emission time

    @property
    def latency_s(self) -> float:
        return self.finished_s - self.submitted_s

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (None until one is emitted)."""
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.submitted_s

    @property
    def tpot_s(self) -> Optional[float]:
        """Mean time per output token after the first (None with < 2)."""
        if self.first_token_s is None or len(self.tokens) < 2 \
                or not self.finished_s:
            return None
        return ((self.finished_s - self.first_token_s)
                / (len(self.tokens) - 1))


@runtime_checkable
class Engine(Protocol):
    """Structural protocol every serving engine implements."""

    def submit(self, prompt, gen: Optional[GenerationConfig] = None) -> int:
        """Enqueue one request; returns its uid."""
        ...

    def step(self) -> bool:
        """Advance the scheduler one iteration; True while work may remain."""
        ...

    def drain(self) -> List[Result]:
        """Run until idle; return Results not yet drained, submission order."""
        ...

    def result(self, uid: int) -> Result:
        ...


class EngineBase:
    """Shared request lifecycle for serving engines.

    Subclass contract:
      * ``_validate(req)`` — raise on inadmissible requests (called by
        ``submit`` before the uid is allocated).
      * ``_step()`` — pop work from ``self._queue`` (deque of
        ``(uid, Request)``), advance it, record tokens into
        ``self._results[uid]`` (via ``_record_token``); return True while
        work may remain. The public ``step()`` wraps it with span tracing
        and compile-vs-steady wall-clock accounting.
      * ``_has_work()`` — anything queued or in flight (default: queue only).
      * ``_ready()`` — worth calling ``step()`` right now (default:
        ``_has_work()``); engines that batch by convoy return False until
        the convoy fills or ``self._flush`` is set.
      * ``_trace_count()`` — total jit (re)traces so far (default 0):
        lets ``step()`` attribute a step's wall time to compilation rather
        than steady-state decode.
      * ``_device_metrics()`` — the engine's device-resident MetricsState
        (or None); ``_metrics_hook(snap)`` — add engine-specific series.
    """

    def __init__(self, *, metrics: bool = True):
        self._queue: Deque[Tuple[int, Request]] = collections.deque()
        self._results: Dict[int, Result] = {}
        self._undrained: List[int] = []
        self._next_uid = 0
        self._clock_origin: Optional[float] = None
        self._flush = False
        self.metrics_enabled = metrics
        self.tracer = SpanTracer(enabled=metrics)
        # compile vs steady step timing (see generate_timed / step())
        self._compile_s = 0.0
        self._steady_s = 0.0
        self._compile_steps = 0
        self._steady_steps = 0

    # -- clock ----------------------------------------------------------

    def _now(self) -> float:
        if self._clock_origin is None:
            return 0.0
        return time.perf_counter() - self._clock_origin

    # -- hooks ----------------------------------------------------------

    def _validate(self, req: Request) -> None:
        pass

    def _has_work(self) -> bool:
        return bool(self._queue)

    def _ready(self) -> bool:
        return self._has_work()

    def _step(self) -> bool:
        raise NotImplementedError

    def _trace_count(self) -> int:
        return 0

    def _device_metrics(self):
        return None

    def _metrics_hook(self, snap: MetricsSnapshot) -> None:
        pass

    def _record_token(self, uid: int, token: int) -> None:
        """Append one generated token, stamping first-token time (TTFT)."""
        res = self._results[uid]
        if not res.tokens:
            res.first_token_s = self._now()
        res.tokens.append(token)

    def step(self) -> bool:
        """Advance the scheduler one iteration (traced + timed). A step
        during which any jitted callable (re)traced counts as compile time;
        all others accumulate into the steady-state step time — the split
        ``generate_timed`` previously conflated."""
        n0 = self._trace_count()
        t0 = time.perf_counter()
        with self.tracer.span("step", engine=type(self).__name__):
            out = self._step()
        dt = time.perf_counter() - t0
        if self._trace_count() > n0:
            self._compile_s += dt
            self._compile_steps += 1
        else:
            self._steady_s += dt
            self._steady_steps += 1
        return out

    @property
    def timing(self) -> Dict[str, float]:
        """Wall-clock accounting over every ``step()`` so far:
        ``compile_s`` (steps that (re)traced a jitted callable, i.e. paid
        compilation), ``steady_s`` total / ``steady_step_s`` mean for the
        remaining steady-state steps."""
        return {
            "compile_s": self._compile_s,
            "compile_steps": float(self._compile_steps),
            "steady_s": self._steady_s,
            "steady_steps": float(self._steady_steps),
            "steady_step_s": (self._steady_s / self._steady_steps
                              if self._steady_steps else 0.0),
        }

    # -- metrics snapshot (host sync happens HERE, at a step boundary) ---

    def metrics(self) -> MetricsSnapshot:
        """One point-in-time snapshot of engine metrics: device-resident
        MoE counters (drained here — the only host transfer), queue/timing
        gauges, and per-request TTFT/TPOT/latency histograms."""
        snap = MetricsSnapshot()
        dm = self._device_metrics()
        if dm is not None:
            s = dm.snapshot()
            for outcome in ("kept_full", "kept_major"):
                snap.counter("repro_moe_subpairs_total", int(s[outcome]),
                             outcome=outcome)
            snap.counter("repro_moe_subpairs_total",
                         int(s["dropped_pairs"]), outcome="dropped")
            snap.counter("repro_moe_subpairs_total",
                         int(s["overflow_pairs"]), outcome="overflow")
            el = s["expert_load"]
            for layer in range(el.shape[0]):
                for expert in range(el.shape[1]):
                    snap.counter("repro_moe_expert_load_total",
                                 int(el[layer, expert]),
                                 layer=layer, expert=expert)
        snap.gauge("repro_queue_depth", len(self._queue))
        t = self.timing
        snap.gauge("repro_engine_compile_s", t["compile_s"])
        snap.gauge("repro_engine_steady_step_s", t["steady_step_s"])
        finished = [r for r in self._results.values() if r.finished_s]
        snap.counter("repro_requests_total", len(self._results),
                     state="submitted")
        snap.counter("repro_requests_total", len(finished), state="finished")
        h_lat = snap.histogram("repro_request_latency_seconds")
        h_ttft = snap.histogram("repro_request_ttft_seconds")
        h_tpot = snap.histogram("repro_request_tpot_seconds")
        for r in finished:
            h_lat.observe(r.latency_s)
            if r.ttft_s is not None:
                h_ttft.observe(r.ttft_s)
            if r.tpot_s is not None:
                h_tpot.observe(r.tpot_s)
        self._metrics_hook(snap)
        return snap

    # -- request lifecycle ----------------------------------------------

    def submit(self, prompt, gen: Optional[GenerationConfig] = None) -> int:
        """Enqueue one request (a prompt array or a ``Request``); returns its
        uid. Admission into compute happens inside ``step()``."""
        if isinstance(prompt, Request):
            if gen is not None:
                raise ValueError("pass gen inside the Request")
            req = prompt
        else:
            req = Request(prompt=prompt,
                          gen=gen if gen is not None else GenerationConfig())
        req = dataclasses.replace(req,
                                  prompt=np.asarray(req.prompt, np.int32))
        self._validate(req)
        if self._clock_origin is None:
            # start the engine clock at the first submission so TTFT /
            # latency are meaningful outside generate_timed too
            self._clock_origin = time.perf_counter()
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append((uid, req))
        self._undrained.append(uid)
        self._results[uid] = Result(
            uid=uid, tokens=[],
            submitted_s=req.arrival if req.arrival else self._now())
        self.tracer.instant("submit", uid=uid,
                            prompt_len=int(len(req.prompt)))
        return uid

    def run(self) -> None:
        """Drive the scheduler until queue and in-flight work are empty."""
        self._flush = True
        try:
            while self._has_work():
                self.step()
        finally:
            self._flush = False

    def drain(self) -> List[Result]:
        """Run to completion and return every Result not yet returned by a
        previous ``drain``/``generate``, in submission order."""
        self.run()
        out = [self._results[u] for u in self._undrained]
        self._undrained = []
        return out

    def result(self, uid: int) -> Result:
        return self._results[uid]

    # -- high-level entry points (wrappers over submit/step/drain) -------

    def generate(self, prompts: Sequence[np.ndarray],
                 gen: GenerationConfig) -> List[Result]:
        """Offline batch entry point: submit every prompt, drain, return
        Results in submission order."""
        uids = [self.submit(p, gen) for p in prompts]
        self.drain()
        return [self._results[u] for u in uids]

    def generate_timed(self, arrivals: Sequence[Tuple[float, np.ndarray,
                                                      GenerationConfig]]
                       ) -> List[Result]:
        """Online entry point: ``arrivals`` is a list of
        (arrival_time_s, prompt, gen). Requests are submitted when the wall
        clock passes their arrival time (Poisson traffic etc.); Results carry
        submitted_s/finished_s for latency accounting."""
        order = sorted(range(len(arrivals)), key=lambda i: arrivals[i][0])
        pending = collections.deque(order)
        self._clock_origin = time.perf_counter()
        uids: Dict[int, int] = {}
        try:
            while pending or self._has_work():
                now = self._now()
                while pending and arrivals[pending[0]][0] <= now:
                    i = pending.popleft()
                    t, prompt, gen = arrivals[i]
                    uid = self.submit(Request(prompt=prompt, gen=gen,
                                              arrival=t))
                    self._results[uid].submitted_s = t
                    uids[i] = uid
                self._flush = not pending
                if not self._ready():
                    if pending:
                        time.sleep(min(0.01, max(
                            0.0, arrivals[pending[0]][0] - self._now())))
                    continue
                self.step()
        finally:
            self._flush = False
            self._clock_origin = None
        self._undrained = [u for u in self._undrained
                           if u not in set(uids.values())]
        return [self._results[uids[i]] for i in range(len(arrivals))]
