"""Paged-KV serving engine: block-granular KV cache + chunked prefill +
prefix caching (paper §4, ROADMAP serving item).

The KV cache is ONE physical page pool per layer (``PagedLayout``); each
decode slot owns a row of a host-side *page table* mapping logical page
index -> physical page. The table is passed to the jitted steps as a traced
int32 array, so page churn (allocation, reuse, eviction) changes VALUES,
never shapes — nothing retraces.

Three mechanisms ride on the indirection:

* **Chunked prefill** — a prompt advances ``chunk_size`` tokens per engine
  ``step()`` through a jitted fixed-shape ``chunk_insert``, interleaved with
  decode for already-active slots: long prompts no longer stall token
  generation for everyone else. Attention reads are trimmed to the same
  static width the monolithic prefill uses (``read_len=max_prompt_len``), so
  chunked logits are bit-identical to one-shot prefill.
* **Prefix caching** — filled prompt pages are registered under a hash of
  (prompt prefix tokens, policy thresholds); a later request with the same
  prefix maps the cached physical pages into its page table (refcounted,
  zero-copy) and starts prefill after them. The last prompt token is always
  recomputed (hits are capped at ``h*ps <= plen-1``) so first-token logits
  exist. Unreferenced cached pages park in an LRU and are evicted only when
  the free list runs dry.
* **Page-0 write sink** — page 0 is never allocated; masked/inactive writes
  are redirected past the pool (``mode="drop"``) or land on page 0, and
  reads beyond a slot's position are validity-masked, so stale data is
  never observed.

Bit-exactness contract (tested): with ``exact_moe`` and a float32 cache,
greedy tokens match ``ContinuousBatchingEngine`` bit-for-bit — decode reads
trim to the contiguous engine's ``context_len`` and chunk reads to its
padded prompt width, keeping every softmax reduction the same static width.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import attention as attn
from ..models import transformer
from ..models.transformer import DistContext
from ..obs import MetricsSnapshot, metrics_spec
from .api import EngineBase, GenerationConfig, Request
from .engine import exact_moe_dist, merge_policy_override


class PageAllocator:
    """Refcounted physical-page allocator with a prefix-cache directory.

    Page 0 is reserved as the write sink for inactive slots and is never
    handed out. A page is in exactly one of three states: *free* (on the
    free stack), *held* (refcount > 0), or *parked* (refcount 0 but still
    registered in the prefix cache — reusable via ``acquire_cached`` and
    evictable in LRU order when the free stack empties)."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._ref = np.zeros(n_pages, np.int32)
        self._cached: Dict[bytes, int] = {}    # prefix key -> physical page
        self._page_key: Dict[int, bytes] = {}  # reverse map
        self._lru: Dict[int, int] = {}         # parked page -> last-use tick
        self._tick = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def available(self) -> int:
        return len(self._free) + len(self._lru)

    # page-state census (page 0, the write sink, is never handed out and
    # is excluded from all three states)
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_parked(self) -> int:
        return len(self._lru)

    @property
    def n_held(self) -> int:
        return self.n_pages - 1 - self.n_free - self.n_parked

    def alloc(self) -> int:
        """Take a fresh page (refcount 1), evicting the LRU-oldest parked
        cached page if the free stack is empty."""
        if self._free:
            page = self._free.pop()
        else:
            page = min(self._lru, key=self._lru.get)
            del self._lru[page]
            del self._cached[self._page_key.pop(page)]
            self.evictions += 1
        self._ref[page] = 1
        return page

    def lookup(self, key: bytes) -> Optional[int]:
        return self._cached.get(key)

    def acquire_cached(self, key: bytes) -> int:
        """Take a reference on the cached page for ``key`` (prefix hit)."""
        page = self._cached[key]
        self._ref[page] += 1
        self._lru.pop(page, None)
        self.hits += 1
        return page

    def register(self, key: bytes, page: int) -> None:
        """Publish a filled, held page under a prefix key. First writer
        wins: an existing registration (same content by construction) is
        kept; a page can carry at most one key."""
        if key in self._cached or page in self._page_key:
            return
        self._cached[key] = page
        self._page_key[page] = key

    def release(self, page: int) -> None:
        """Drop one reference; at zero the page parks (if registered) or
        returns to the free stack."""
        self._ref[page] -= 1
        assert self._ref[page] >= 0
        if self._ref[page] == 0:
            if page in self._page_key:
                self._tick += 1
                self._lru[page] = self._tick
            else:
                self._free.append(page)


@dataclasses.dataclass
class _SlotState:
    uid: int
    gen: GenerationConfig
    prompt: np.ndarray
    n_pages: int                      # page-table entries this slot holds
    next_start: int = 0               # next prompt token to prefill
    prefilling: bool = True
    n_emitted: int = 0


class PagedEngine(EngineBase):
    """Paged-KV continuous-batching engine with chunked prefill and prefix
    caching. Speaks the unified ``submit()``/``step()``/``drain()`` API;
    with ``exact_moe`` + float32 cache its greedy tokens are bit-identical
    to ``ContinuousBatchingEngine`` for the same requests."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 page_size: int = 16, chunk_size: int = 64,
                 max_prompt_len: int = 512, max_new_tokens: int = 128,
                 n_pages: Optional[int] = None, pad_token: int = 0,
                 dist: Optional[DistContext] = None, exact_moe: bool = True,
                 cache_dtype=jnp.bfloat16, prefix_cache: bool = True,
                 metrics: bool = True):
        if (cfg.family in ("audio", "ssm", "hybrid")
                or cfg.attn_kind == "mla" or cfg.frontend):
            raise NotImplementedError(
                "paged serving supports GQA attention decoder-only text "
                "models (chunked prefill has no recurrent-state or "
                "frontend-token analog yet)")
        super().__init__(metrics=metrics)
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.page_size = page_size
        self.chunk_size = chunk_size
        self.pad_token = pad_token
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens
        self.prefix_cache = prefix_cache
        if exact_moe and cfg.is_moe:
            dist = exact_moe_dist(dist)
        self.dist = dist
        # one slot's worth of logical pages covers prompt + decode budget;
        # the decode read is trimmed to exactly the contiguous engine's
        # context_len so both engines reduce over the same static width
        self.context_len = max_prompt_len + max_new_tokens
        self.pages_per_slot = -(-self.context_len // page_size)
        if n_pages is None:
            n_pages = 1 + n_slots * self.pages_per_slot
        self.n_pages = n_pages
        self._alloc = PageAllocator(n_pages)
        self._layout = attn.PagedLayout(page_size)
        self._page_table = np.zeros((n_slots, self.pages_per_slot), np.int32)
        self._cache = transformer.init_paged_cache(
            cfg, n_pages, page_size, n_slots, dtype=cache_dtype,
            metrics_spec=metrics_spec(cfg, params) if metrics else None)
        self._slots: List[Optional[_SlotState]] = [None] * n_slots
        self._last = np.full((n_slots, 1), pad_token, np.int32)
        self._active = np.zeros((n_slots,), bool)

        # per-slot policy stacking (same scheme as the continuous engine)
        self._base_policy = dist.policy if dist is not None else None
        self._policy_treedef = None
        if self._base_policy is not None:
            leaves, treedef = jax.tree_util.tree_flatten(self._base_policy)
            try:
                base = np.asarray([float(l) for l in leaves], np.float32)
            except (TypeError, ValueError):
                base = None
            if base is not None:
                self._policy_treedef = treedef
                self._base_leaves = base
                self._slot_pol = np.tile(base[:, None], (1, n_slots))

        # trace counters: incremented only when jit actually (re)traces
        self.chunk_traces = 0
        self.decode_traces = 0
        layout = self._layout
        mpl = max_prompt_len
        ctx = self.context_len

        def chunk_insert(params, tokens, slot, start, valid_len, cache,
                         page_table, policy):
            self.chunk_traces += 1
            d = dist if (dist is None or policy is None) else \
                dataclasses.replace(dist, policy=policy)
            logits, new = transformer.chunk_step(
                params, tokens, slot, start, valid_len, cache, cfg,
                layout=layout, page_table=page_table, read_len=mpl, dist=d)
            last = jax.lax.dynamic_index_in_dim(logits[0], valid_len - 1,
                                                axis=0, keepdims=False)
            return jnp.argmax(last).astype(jnp.int32), new

        def decode(params, tokens, cache, active, page_table, policy):
            self.decode_traces += 1
            d = dist if (dist is None or policy is None) else \
                dataclasses.replace(dist, policy=policy)
            logits, new = transformer.decode_step(
                params, tokens, cache, cfg, dist=d, layout=layout,
                page_table=page_table, write_mask=active, read_len=ctx)
            new["pos"] = jnp.where(active, new["pos"], cache["pos"])
            greedy = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return logits[:, -1], greedy, new

        self._chunk_insert = jax.jit(chunk_insert, donate_argnums=(5,))
        self._decode = jax.jit(decode, donate_argnums=(2,))

        # scheduler stats
        self.n_admitted = 0
        self.n_retired = 0
        self.max_concurrency = 0
        self.decode_steps = 0
        self.chunk_steps = 0              # jitted chunk_insert invocations
        self.prefill_tokens = 0           # prompt tokens actually prefilled

    # -- unified request API --------------------------------------------

    def _validate(self, req: Request) -> None:
        if len(np.asarray(req.prompt)) > self.max_prompt_len:
            raise ValueError(
                f"prompt length {len(np.asarray(req.prompt))} exceeds engine "
                f"max_prompt_len {self.max_prompt_len}")
        if req.gen.max_new_tokens > self.max_new_tokens:
            raise ValueError(
                f"request max_new_tokens {req.gen.max_new_tokens} "
                f"exceeds engine budget {self.max_new_tokens}")
        if req.gen.policy is not None:
            if self._policy_treedef is None:
                raise ValueError(
                    "per-request policy override requires an engine built "
                    "with a scalar-threshold base policy (DistContext.policy)")
            merge_policy_override(self._base_policy, req.gen.policy)

    def _has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    # -- prefix-cache keys ----------------------------------------------

    def _policy_bytes(self, gen: GenerationConfig) -> bytes:
        """KV content depends on MoE routing thresholds (earlier layers'
        MoE feeds later layers' K/V), so the policy is part of the key."""
        if self._policy_treedef is None:
            return b""
        return self._request_leaves(gen).tobytes()

    def _prefix_key(self, prompt: np.ndarray, n_tokens: int,
                    gen: GenerationConfig) -> bytes:
        h = hashlib.sha256()
        h.update(np.ascontiguousarray(prompt[:n_tokens]).tobytes())
        h.update(self._policy_bytes(gen))
        return h.digest()

    # -- policy stacking (same scheme as the continuous engine) ----------

    def _request_leaves(self, gen: GenerationConfig):
        if gen.policy is None:
            return self._base_leaves
        leaves, _ = jax.tree_util.tree_flatten(gen.policy)
        return np.asarray([float(l) for l in leaves], np.float32)

    def _stacked_policy(self):
        if self._policy_treedef is None:
            return None
        return jax.tree_util.tree_unflatten(
            self._policy_treedef,
            [jnp.asarray(row) for row in self._slot_pol])

    def _slot_policy(self, gen: GenerationConfig):
        if self._policy_treedef is None:
            return None
        return jax.tree_util.tree_unflatten(
            self._policy_treedef,
            [jnp.asarray(l) for l in self._request_leaves(gen)])

    # -- admission / retirement ------------------------------------------

    def _admit(self) -> int:
        """FIFO admission with head-of-line blocking: a request enters a
        free slot only if the allocator can cover its FULL page demand
        (prompt + decode budget), after prefix-cache reuse. Hit pages map
        straight into the slot's page table; prefill starts after them."""
        admitted = 0
        for slot in range(self.n_slots):
            if not self._queue:
                break
            if self._slots[slot] is not None:
                continue
            uid, req = self._queue[0]
            plen = len(req.prompt)
            ps = self.page_size
            need_total = -(-(plen + req.gen.max_new_tokens) // ps)
            # longest run of cached full prompt pages, capped so the last
            # prompt token is recomputed (its logits emit the first token)
            hit_keys: List[bytes] = []
            if self.prefix_cache:
                h = 1
                while h * ps <= plen - 1:
                    key = self._prefix_key(req.prompt, h * ps, req.gen)
                    if self._alloc.lookup(key) is None:
                        break
                    hit_keys.append(key)
                    h += 1
            if self._alloc.available() < need_total - len(hit_keys):
                break                      # head-of-line: keep FIFO order
            self._queue.popleft()
            pages = [self._alloc.acquire_cached(k) for k in hit_keys]
            # hit rate is over lookup-eligible prompt pages (h*ps <= plen-1)
            self._alloc.misses += max(0, (plen - 1) // ps - len(hit_keys))
            pages += [self._alloc.alloc()
                      for _ in range(need_total - len(hit_keys))]
            row = np.zeros(self.pages_per_slot, np.int32)
            row[:len(pages)] = pages
            self._page_table[slot] = row
            if self._policy_treedef is not None:
                self._slot_pol[:, slot] = self._request_leaves(req.gen)
            start = len(hit_keys) * ps
            self._slots[slot] = _SlotState(
                uid=uid, gen=req.gen, prompt=req.prompt, n_pages=len(pages),
                next_start=start)
            self._cache["pos"] = self._cache["pos"].at[slot].set(start)
            admitted += 1
            self.n_admitted += 1
        return admitted

    def _retire(self, slot: int):
        st = self._slots[slot]
        self._results[st.uid].finished_s = self._now()
        self.tracer.instant("retire", uid=st.uid, slot=slot,
                            n_tokens=st.n_emitted)
        for page in self._page_table[slot]:
            if page:
                self._alloc.release(int(page))
        self._page_table[slot] = 0
        self._slots[slot] = None
        self._active[slot] = False
        self._last[slot, 0] = self.pad_token
        if self._policy_treedef is not None:
            self._slot_pol[:, slot] = self._base_leaves
        self.n_retired += 1

    def _emit(self, slot: int, token: int):
        st = self._slots[slot]
        self._record_token(st.uid, token)
        st.n_emitted += 1
        if token == st.gen.eos_token or st.n_emitted >= st.gen.max_new_tokens:
            self._retire(slot)

    # -- prefill / decode ------------------------------------------------

    def _advance_prefill(self) -> bool:
        """Advance exactly ONE prefilling slot by ONE chunk (fixed-shape
        jitted step — the per-step prompt work is bounded by chunk_size).
        On the final chunk the slot activates for decode, its first greedy
        token is emitted, and its filled prompt pages are registered in the
        prefix cache."""
        slot = next((i for i, s in enumerate(self._slots)
                     if s is not None and s.prefilling), None)
        if slot is None:
            return False
        st = self._slots[slot]
        plen = len(st.prompt)
        start = st.next_start
        valid = min(self.chunk_size, plen - start)
        toks = np.full((1, self.chunk_size), self.pad_token, np.int32)
        toks[0, :valid] = st.prompt[start:start + valid]
        t0 = time.perf_counter()
        with self.tracer.span("prefill_chunk", uid=st.uid, slot=slot,
                              start=start, n_tokens=valid), \
                jax.profiler.TraceAnnotation("engine_prefill_chunk"):
            first, self._cache = self._chunk_insert(
                self.params, jnp.asarray(toks), jnp.asarray(slot, jnp.int32),
                jnp.asarray(start, jnp.int32), jnp.asarray(valid, jnp.int32),
                self._cache, jnp.asarray(self._page_table),
                self._slot_policy(st.gen))
        self._results[st.uid].prefill_s += time.perf_counter() - t0
        self.chunk_steps += 1
        self.prefill_tokens += valid
        st.next_start = start + valid
        if st.next_start < plen:
            return True
        # prefill complete: publish full prompt pages, activate for decode
        if self.prefix_cache:
            ps = self.page_size
            for h in range(1, plen // ps + 1):
                self._alloc.register(
                    self._prefix_key(st.prompt, h * ps, st.gen),
                    int(self._page_table[slot, h - 1]))
        st.prefilling = False
        self._active[slot] = True
        self._last[slot, 0] = int(first)
        self._emit(slot, int(first))
        self.max_concurrency = max(self.max_concurrency,
                                   int(self._active.sum()))
        return True

    def _step(self) -> bool:
        """One scheduler iteration: admit queued requests into free slots,
        advance one prefilling slot by one chunk, then one batched decode
        step over all active slots. Returns True while work may remain."""
        self._admit()
        self._advance_prefill()
        if not self._active.any():
            return self._has_work()
        with self.tracer.span("decode", batch=int(self._active.sum())), \
                jax.profiler.TraceAnnotation("engine_decode"):
            logits, greedy, self._cache = self._decode(
                self.params, jnp.asarray(self._last), self._cache,
                jnp.asarray(self._active), jnp.asarray(self._page_table),
                self._stacked_policy())
        self.decode_steps += 1
        greedy_np = np.asarray(greedy)
        need_sampling = any(st is not None and not st.prefilling
                            and st.gen.temperature > 0 for st in self._slots)
        logits_np = np.asarray(logits) if need_sampling else None
        for slot in range(self.n_slots):
            st = self._slots[slot]
            if st is None or st.prefilling:
                continue
            if st.gen.temperature > 0:
                key = jax.random.fold_in(
                    jax.random.fold_in(jax.random.PRNGKey(st.gen.seed),
                                       st.uid), st.n_emitted)
                tok = int(jax.random.categorical(
                    key, jnp.asarray(logits_np[slot]) / st.gen.temperature))
            else:
                tok = int(greedy_np[slot])
            self._last[slot, 0] = tok
            self._emit(slot, tok)
        return True

    # -- stats -----------------------------------------------------------

    @property
    def prefix_hits(self) -> int:
        return self._alloc.hits

    @property
    def prefix_misses(self) -> int:
        return self._alloc.misses

    @property
    def prefix_hit_rate(self) -> float:
        tot = self._alloc.hits + self._alloc.misses
        return self._alloc.hits / tot if tot else 0.0

    @property
    def overflow_pairs(self) -> int:
        m = self._device_metrics()
        if m is not None:
            return int(m.overflow_pairs)
        if isinstance(self._cache, dict) and "moe_overflow" in self._cache:
            return int(dict.__getitem__(self._cache, "moe_overflow"))
        return 0

    @property
    def free_slots(self) -> int:
        return sum(s is None for s in self._slots)

    @property
    def queued(self) -> int:
        return len(self._queue)

    # -- observability hooks (EngineBase) --------------------------------

    def _trace_count(self) -> int:
        return self.chunk_traces + self.decode_traces

    def _device_metrics(self):
        if isinstance(self._cache, dict):
            return self._cache.get("metrics")
        return None

    def _metrics_hook(self, snap: MetricsSnapshot) -> None:
        snap.counter("repro_prefix_cache_total", float(self._alloc.hits),
                     event="hit")
        snap.counter("repro_prefix_cache_total", float(self._alloc.misses),
                     event="miss")
        snap.counter("repro_prefix_cache_total", float(self._alloc.evictions),
                     event="eviction")
        snap.gauge("repro_page_pool_pages", float(self._alloc.n_free),
                   state="free")
        snap.gauge("repro_page_pool_pages", float(self._alloc.n_held),
                   state="held")
        snap.gauge("repro_page_pool_pages", float(self._alloc.n_parked),
                   state="parked")
        snap.gauge("repro_engine_slots", float(self.n_slots))
        snap.gauge("repro_engine_free_slots", float(self.free_slots))
        snap.counter("repro_engine_decode_steps_total",
                     float(self.decode_steps))
        snap.counter("repro_engine_chunk_steps_total",
                     float(self.chunk_steps))
        snap.counter("repro_requests_admitted_total", float(self.n_admitted))
        snap.counter("repro_requests_retired_total", float(self.n_retired))

    def reset_stats(self):
        """Zero scheduler statistics (trace counters are kept: warmup
        compiles are still traces; allocator hit/miss counters are kept:
        the prefix cache's state survives across runs)."""
        self.n_admitted = self.n_retired = 0
        self.max_concurrency = 0
        self.decode_steps = 0
        self.chunk_steps = 0
        self.prefill_tokens = 0
