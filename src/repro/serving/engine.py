"""Serving engines for the DualSparse-MoE inference system (paper §4).

Both engines implement the unified request API (``serving.api``:
``submit()`` / ``step()`` / ``drain()``) and share the jitted model steps:

``ServingEngine`` — the synchronized-batch baseline: requests are grouped to
a common (padded) prompt length, prefilled in one jitted call, then decoded
together with ONE shared absolute position. One ``step()`` serves one convoy
batch to completion. This is the exact setting of the paper's efficiency
evaluation (fixed 500-token prompts, 100 output tokens, §5.3.2) and is kept
as the benchmark baseline.

``ContinuousBatchingEngine`` — slot-based continuous batching for heavy
heterogeneous traffic: a fixed number of decode *slots* (the batch dimension
of one jitted decode step), an admission queue, per-slot absolute positions
and ragged KV handling (cache["pos"] is a (n_slots,) vector), per-request
EOS/budget retirement that frees slots mid-decode for waiting requests, and
a jitted fixed-shape prefill-insert so slot churn never retraces. One
``step()`` is one admit+decode scheduler iteration.

MoE sparsity is configured by ONE ``SparsityPolicy`` on the DistContext
(``core.policy``: none/1t/2t/load_aware/per_layer); requests may override
threshold values per request via ``GenerationConfig.policy`` (same policy
family) — the continuous engine stacks per-slot threshold leaves into the
jitted decode step, so mixed-threshold traffic co-decodes without retrace.

Request isolation: with ``exact_moe`` (continuous default) the MoE dispatch
capacity is set so no token-expert pair is ever dropped by overflow, making
each request's tokens independent of what else happens to be co-batched —
greedy outputs are bit-identical to a synchronized run of the same
requests. Overflow drops that do occur (non-exact deployments) are counted
and surfaced via ``engine.overflow_pairs``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.policy import NoDrop, SparsityPolicy
from ..models import model as M
from ..models import transformer
from ..models.transformer import DistContext
from ..obs import MetricsSnapshot, metrics_spec
from .api import EngineBase, GenerationConfig, Request, Result  # noqa: F401


def merge_policy_override(base: Optional[SparsityPolicy],
                          override: SparsityPolicy) -> SparsityPolicy:
    """Graft a per-request override's threshold LEAVES onto the engine base
    policy's static hints (exact_capacity, capacity_factor, ...): requests
    choose values, the deployment keeps its execution guarantees. Raises
    when the override is a different policy family."""
    if base is None:
        return override
    if type(override) is not type(base):
        raise ValueError(
            f"per-request policy must match the engine's policy family "
            f"{base.name!r} (got {override.name!r}); only threshold values "
            f"may differ")
    leaves = jax.tree_util.tree_flatten(override)[0]
    base_leaves, treedef = jax.tree_util.tree_flatten(base)
    assert len(leaves) == len(base_leaves)   # same class => same dynamics
    return jax.tree_util.tree_unflatten(treedef, leaves)


def exact_moe_dist(dist: Optional[DistContext]) -> DistContext:
    """A DistContext whose dispatch-path MoE never drops a token-expert pair
    by capacity overflow (capacity == T), making outputs
    batch-composition-invariant. The existing sparsity policy is preserved
    with its ``exact_capacity`` hint set; no policy means NoDrop + exact
    capacity."""
    if dist is not None:
        pol = dist.policy if dist.policy is not None else NoDrop()
        return dataclasses.replace(
            dist, policy=dataclasses.replace(pol, exact_capacity=True))
    from ..launch.mesh import make_host_mesh
    return DistContext(mesh=make_host_mesh(1), moe_impl="dispatch",
                       policy=NoDrop(exact_capacity=True))


class ServingEngine(EngineBase):
    """Synchronized-batch engine around jitted prefill/serve steps."""

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 8,
                 max_prompt_len: int = 512, max_new_tokens: int = 128,
                 window: int = 0, pad_token: int = 0,
                 dist: Optional[DistContext] = None,
                 exact_moe: bool = False, cache_dtype=jnp.bfloat16,
                 metrics: bool = True):
        super().__init__(metrics=metrics)
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.window = window
        self.pad_token = pad_token
        if exact_moe and cfg.is_moe:
            dist = exact_moe_dist(dist)
        self.dist = dist
        # device-resident obs MetricsState summed over served batches (one
        # lazy add per batch, drained only by engine.metrics()); None until
        # the first metrics-enabled batch finishes
        self._dev_metrics = None
        ctx = M.context_len_for(cfg, max_prompt_len, max_new_tokens)
        self.context_len = ctx
        # trace counters: incremented only when jit actually (re)traces
        self.prefill_traces = 0
        self.decode_traces = 0

        # the sparsity policy is a jit ARGUMENT (pytree): per-call overrides
        # with the same structure change only threshold leaves -> no retrace
        def prefill_step(params, batch, policy):
            self.prefill_traces += 1
            d = dist if (dist is None or policy is None) else \
                dataclasses.replace(dist, policy=policy)
            return M.make_prefill_step(cfg, cache_len=ctx, window=window,
                                       dist=d, cache_dtype=cache_dtype,
                                       metrics=metrics)(params, batch)

        def serve_step(params, token, cache, policy):
            self.decode_traces += 1
            d = dist if (dist is None or policy is None) else \
                dataclasses.replace(dist, policy=policy)
            return M.make_serve_step(cfg, window=window,
                                     dist=d)(params, token, cache)

        self._prefill = jax.jit(prefill_step)
        self._serve = jax.jit(serve_step)
        self.max_prompt_len = max_prompt_len

    def _policy_for(self, gen: GenerationConfig) -> Optional[SparsityPolicy]:
        base = self.dist.policy if self.dist is not None else None
        if gen.policy is None:
            return base
        if self.dist is None:
            raise ValueError("per-request policy override needs a "
                             "DistContext-backed engine (MoE dispatch path)")
        # keep the engine's execution hints (e.g. exact_moe's exact
        # capacity); the request only chooses threshold values
        return merge_policy_override(base, gen.policy)

    def _make_batch(self, prompts: List[np.ndarray]) -> Dict[str, jax.Array]:
        """Right-align (left-pad) prompts to the common max length so every
        real token sits at the end — causal attention then gives each request
        a correct suffix context (pads influence only via their K/V, which we
        accept for pad-light batches; equal-length prompts are exact)."""
        L = max(len(p) for p in prompts)
        toks = np.full((len(prompts), L), self.pad_token, np.int32)
        for i, p in enumerate(prompts):
            toks[i, L - len(p):] = p
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "vision":
            batch["frontend"] = jnp.zeros(
                (len(prompts), self.cfg.n_frontend_tokens, self.cfg.d_model))
        if self.cfg.frontend == "audio":
            batch["audio_embeds"] = jnp.zeros(
                (len(prompts), self.cfg.n_frontend_tokens, self.cfg.d_model))
        return batch

    # -- unified request API --------------------------------------------

    def _validate(self, req: Request) -> None:
        self._policy_for(req.gen)        # raises on family mismatch

    def _ready(self) -> bool:
        """Convoy semantics: wait for a full batch while more traffic is
        still arriving; a flush (``run``/end of trace) serves partials."""
        if not self._queue:
            return False
        return self._flush or len(self._queue) >= self.batch_size

    @staticmethod
    def _policy_sig(gen: GenerationConfig):
        if gen.policy is None:
            return None
        return (type(gen.policy),
                tuple(float(l) for l in
                      jax.tree_util.tree_flatten(gen.policy)[0]))

    def _trace_count(self) -> int:
        return self.prefill_traces + self.decode_traces

    def _device_metrics(self):
        return self._dev_metrics

    def _metrics_hook(self, snap: MetricsSnapshot) -> None:
        snap.gauge("repro_engine_batch_size", self.batch_size)

    def _step(self) -> bool:
        """Serve ONE convoy batch to completion: pop up to ``batch_size``
        queued requests (cut early at a per-request policy-override change —
        the policy is one jit argument per batch), prefill them together,
        decode with per-request EOS/budget/sampling. Returns True while more
        requests are queued."""
        if not self._queue:
            return False
        batch = [self._queue.popleft()]
        sig = self._policy_sig(batch[0][1].gen)
        while (len(batch) < self.batch_size and self._queue
               and self._policy_sig(self._queue[0][1].gen) == sig):
            batch.append(self._queue.popleft())
        self._run_batch(batch)
        return bool(self._queue)

    def _run_batch(self, batch: List[Tuple[int, Request]]) -> None:
        uids = [u for u, _ in batch]
        gens = [r.gen for _, r in batch]
        B = len(batch)
        b = self._make_batch([r.prompt for _, r in batch])
        policy = self._policy_for(gens[0])
        t0 = time.perf_counter()
        with self.tracer.span("prefill", batch=B):
            with jax.profiler.TraceAnnotation("engine_prefill"):
                logits, cache = self._prefill(self.params, b, policy)
            logits.block_until_ready()
        t_prefill = time.perf_counter() - t0
        last = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        done = np.zeros(B, bool)
        max_steps = max(g.max_new_tokens for g in gens)
        t0 = time.perf_counter()
        with self.tracer.span("decode_loop", batch=B):
            for step in range(max_steps):
                last_np = np.asarray(last)
                for i in range(B):
                    if done[i]:
                        continue
                    self._record_token(uids[i], int(last_np[i, 0]))
                    res = self._results[uids[i]]
                    if (last_np[i, 0] == gens[i].eos_token
                            or len(res.tokens) >= gens[i].max_new_tokens):
                        done[i] = True
                if done.all():
                    break
                with jax.profiler.TraceAnnotation("engine_decode"):
                    logits, cache = self._serve(self.params, last, cache,
                                                policy)
                last = self._next_tokens(logits, gens, uids, step)
        t_decode = time.perf_counter() - t0
        # drain the batch's device metrics into the engine accumulator with
        # ONE lazy device-side add — no host transfer until .metrics()
        m = cache.get("metrics") if isinstance(cache, dict) else None
        if m is not None:
            self._dev_metrics = m if self._dev_metrics is None \
                else self._dev_metrics + m
        now = self._now()
        for u in uids:
            self._results[u].prefill_s = t_prefill
            self._results[u].decode_s = t_decode
            self._results[u].finished_s = now
            self.tracer.instant("retire", uid=u)

    @property
    def overflow_pairs(self) -> int:
        """Total MoE capacity-overflow drops across every batch served
        (reads the device-resident obs MetricsState — one scalar
        transfer, no per-step sync)."""
        if self._dev_metrics is None:
            return 0
        return int(self._dev_metrics.overflow_pairs)

    def _next_tokens(self, logits, gens, uids, step):
        greedy = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        if all(g.temperature == 0 for g in gens):
            return greedy
        greedy_np = np.asarray(greedy)
        toks = np.empty((len(gens), 1), np.int32)
        for i, g in enumerate(gens):
            if g.temperature > 0:
                key = jax.random.fold_in(
                    jax.random.fold_in(jax.random.PRNGKey(g.seed),
                                       uids[i]), step)
                toks[i, 0] = int(jax.random.categorical(
                    key, logits[i, -1] / g.temperature))
            else:
                toks[i, 0] = greedy_np[i, 0]
        return jnp.asarray(toks)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SlotState:
    uid: int
    gen: GenerationConfig
    n_emitted: int = 0


class ContinuousBatchingEngine(EngineBase):
    """Slot-based continuous-batching engine.

    * ``n_slots`` decode slots form the fixed batch dimension of ONE jitted
      decode step; admission/retirement never changes traced shapes, so slot
      churn never retraces (see ``decode_traces`` / ``prefill_traces``).
    * Prompts are right-padded to ``max_prompt_len`` and prefilled one
      request at a time by a jitted *prefill-insert* that writes the new
      request's KV (and its first greedy token) into a free slot of the
      shared ragged cache; ``cache["pos"]`` holds per-slot absolute
      positions, so requests at different depths decode together.
    * A request retires on EOS or budget exhaustion, immediately freeing its
      slot for the next queued request — mid-decode admission.

    Right-padding is exact for causal attention (pad K/V sits *after* every
    real token and is masked by per-slot validity until overwritten by
    decoded tokens); sliding-window (ring) caches would break that layout,
    so ``window`` is not supported here.

    For MoE models ``exact_moe=True`` (default) pins dispatch capacity to
    the token count so expert overflow can never silently drop a pair —
    request outputs are then independent of co-batched traffic and greedy
    tokens match a synchronized run bit-for-bit.
    """

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_prompt_len: int = 512, max_new_tokens: int = 128,
                 pad_token: int = 0, dist: Optional[DistContext] = None,
                 exact_moe: bool = True, cache_dtype=jnp.bfloat16,
                 metrics: bool = True):
        if cfg.family in ("audio", "ssm", "hybrid"):
            # ssm/hybrid: the Mamba recurrence runs over trailing pad tokens
            # during right-padded prefill and pollutes the captured decode
            # state — attention's per-slot validity masking has no recurrent
            # analog, so these families need chunked prefill (ROADMAP).
            raise NotImplementedError(
                f"continuous batching supports attention-based decoder-only "
                f"families, not {cfg.family!r}")
        super().__init__(metrics=metrics)
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.pad_token = pad_token
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens
        if exact_moe and cfg.is_moe:
            dist = exact_moe_dist(dist)
            if dist.moe_impl == "setp":
                import warnings
                warnings.warn(
                    "exact_moe only governs the dispatch MoE path; the setp "
                    "(shard_map EP) path uses its own capacity factors, so "
                    "outputs may depend on co-batched traffic", stacklevel=2)
        self.dist = dist
        self.context_len = M.context_len_for(cfg, max_prompt_len,
                                             max_new_tokens)
        self._prefix = (cfg.n_frontend_tokens if cfg.frontend == "vision"
                        else 0)
        # Per-slot sparsity policies: the base policy's threshold leaves are
        # stacked into (n_slots,) vectors and passed to the jitted decode as
        # a pytree ARGUMENT, so requests with per-request threshold
        # overrides (GenerationConfig.policy, same family) co-decode in one
        # fixed-shape step — values change, nothing retraces.
        self._base_policy = dist.policy if dist is not None else None
        self._policy_treedef = None
        if self._base_policy is not None:
            leaves, treedef = jax.tree_util.tree_flatten(self._base_policy)
            try:
                base = np.asarray([float(l) for l in leaves], np.float32)
            except (TypeError, ValueError):
                base = None        # non-scalar leaves: no per-slot stacking
            if base is not None:
                self._policy_treedef = treedef
                self._base_leaves = base
                self._slot_pol = np.tile(base[:, None], (1, n_slots))
        # trace counters: incremented only when jit actually (re)traces
        self.prefill_traces = 0
        self.decode_traces = 0
        ctx_len = self.context_len

        def prefill_insert(params, tokens, valid_len, slot, cache, policy):
            self.prefill_traces += 1
            d = dist if (dist is None or policy is None) else \
                dataclasses.replace(dist, policy=policy)
            batch = {"tokens": tokens}
            if cfg.frontend == "vision":
                batch["frontend"] = jnp.zeros(
                    (1, cfg.n_frontend_tokens, cfg.d_model))
            logits, small = transformer.prefill(
                params, batch, cfg, cache_len=ctx_len, dist=d,
                cache_dtype=cache_dtype, metrics=metrics)
            last = jax.lax.dynamic_index_in_dim(logits[0], valid_len - 1,
                                                axis=0, keepdims=False)
            first_tok = jnp.argmax(last).astype(jnp.int32)
            # per-slot KV layers are batch-inserted; the engine-wide obs
            # seam ("metrics" / legacy "moe_overflow") merges additively
            small.pop("pos")
            m_small = small.pop("metrics", None)
            of_small = small.pop("moe_overflow", None)
            skip = ("pos", "metrics", "moe_overflow")
            rest = {k: v for k, v in cache.items() if k not in skip}
            small = dict(small)      # match rest's plain-dict treedef

            def ins(big, sm):
                start = (0, slot) + (0,) * (big.ndim - 2)
                return jax.lax.dynamic_update_slice(
                    big, sm.astype(big.dtype), start)

            new = transformer.ObsCache(jax.tree.map(ins, rest, small))
            new["pos"] = cache["pos"].at[slot].set(
                self._prefix + valid_len)
            if "metrics" in cache:
                new["metrics"] = cache["metrics"] + m_small \
                    if m_small is not None else cache["metrics"]
            elif "moe_overflow" in cache:
                new["moe_overflow"] = cache["moe_overflow"] + (
                    of_small if of_small is not None else 0)
            return first_tok, new

        def decode(params, tokens, cache, active, policy):
            self.decode_traces += 1
            d = dist if (dist is None or policy is None) else \
                dataclasses.replace(dist, policy=policy)
            logits, new = transformer.decode_step(params, tokens, cache, cfg,
                                                  dist=d)
            # inactive slots hold their position (their writes land on a
            # fixed, fully-overwritten-on-admit slot — harmless by design)
            new["pos"] = jnp.where(active, new["pos"], cache["pos"])
            greedy = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return logits[:, -1], greedy, new

        # the engine discards the previous cache on every call, so both steps
        # donate it — decode updates one token row in place instead of
        # copying the whole (n_layers, n_slots, context_len, ...) cache
        self._prefill_insert = jax.jit(prefill_insert, donate_argnums=(4,))
        self._decode = jax.jit(decode, donate_argnums=(2,))
        spec = metrics_spec(cfg, params) if metrics else None
        self._cache = M.init_cache(cfg, n_slots, self.context_len,
                                   per_slot_pos=True, dtype=cache_dtype,
                                   metrics_spec=spec)
        self._slots: List[Optional[_SlotState]] = [None] * n_slots
        self._last = np.full((n_slots, 1), pad_token, np.int32)
        self._active = np.zeros((n_slots,), bool)
        # scheduler stats
        self.n_admitted = 0
        self.n_retired = 0
        self.max_concurrency = 0
        self.decode_steps = 0

    # -- unified request API --------------------------------------------

    def _validate(self, req: Request) -> None:
        if len(np.asarray(req.prompt)) > self.max_prompt_len:
            raise ValueError(
                f"prompt length {len(np.asarray(req.prompt))} exceeds engine "
                f"max_prompt_len {self.max_prompt_len}")
        if req.gen.max_new_tokens > self.max_new_tokens:
            raise ValueError(
                f"request max_new_tokens {req.gen.max_new_tokens} "
                f"exceeds engine budget {self.max_new_tokens}")
        if req.gen.policy is not None:
            if self._policy_treedef is None:
                raise ValueError(
                    "per-request policy override requires an engine built "
                    "with a scalar-threshold base policy (DistContext.policy)")
            # same family required; static hints (exact capacity etc.) stay
            # the engine's — only the override's threshold leaves are used
            merge_policy_override(self._base_policy, req.gen.policy)

    def _has_work(self) -> bool:
        return bool(self._queue) or bool(self._active.any())

    # -- scheduling primitives ------------------------------------------

    def _request_leaves(self, gen: GenerationConfig):
        """Validated threshold leaves for a request (base values when the
        request carries no override)."""
        if gen.policy is None:
            return self._base_leaves
        leaves, treedef = jax.tree_util.tree_flatten(gen.policy)
        return np.asarray([float(l) for l in leaves], np.float32)

    def _stacked_policy(self):
        """The per-slot policy pytree for one decode step (threshold leaves
        shaped (n_slots,)), or None when the base DistContext's policy is
        used as a closure constant."""
        if self._policy_treedef is None:
            return None
        return jax.tree_util.tree_unflatten(
            self._policy_treedef,
            [jnp.asarray(row) for row in self._slot_pol])

    def _retire(self, slot: int):
        st = self._slots[slot]
        self._results[st.uid].finished_s = self._now()
        self.tracer.instant("retire", uid=st.uid, slot=slot,
                            n_tokens=st.n_emitted)
        self._slots[slot] = None
        self._active[slot] = False
        self._last[slot, 0] = self.pad_token
        if self._policy_treedef is not None:
            self._slot_pol[:, slot] = self._base_leaves
        self.n_retired += 1

    def _admit(self) -> int:
        """Move queued requests into free slots (jitted prefill-insert each).
        Returns the number admitted. A request whose first token already
        terminates it (eos / budget 1 reached) retires immediately."""
        admitted = 0
        for slot in range(self.n_slots):
            if not self._queue:
                break
            if self._slots[slot] is not None:
                continue
            uid, req = self._queue.popleft()
            toks = np.full((1, self.max_prompt_len), self.pad_token, np.int32)
            toks[0, :len(req.prompt)] = req.prompt
            req_policy = None
            if self._policy_treedef is not None:
                leaves = self._request_leaves(req.gen)
                self._slot_pol[:, slot] = leaves
                req_policy = jax.tree_util.tree_unflatten(
                    self._policy_treedef, [jnp.asarray(l) for l in leaves])
            t0 = time.perf_counter()
            with self.tracer.span("prefill_insert", uid=uid, slot=slot,
                                  prompt_len=len(req.prompt)), \
                    jax.profiler.TraceAnnotation("engine_prefill_insert"):
                first, self._cache = self._prefill_insert(
                    self.params, jnp.asarray(toks),
                    jnp.asarray(len(req.prompt), jnp.int32),
                    jnp.asarray(slot, jnp.int32), self._cache, req_policy)
                first = int(first)
            res = self._results[uid]
            res.prefill_s = time.perf_counter() - t0
            self._slots[slot] = _SlotState(uid=uid, gen=req.gen)
            self._active[slot] = True
            self._last[slot, 0] = first
            self._emit(slot, first)
            admitted += 1
            self.n_admitted += 1
        self.max_concurrency = max(self.max_concurrency,
                                   int(self._active.sum()))
        return admitted

    def _emit(self, slot: int, token: int):
        """Record one generated token for the slot's request; retire on EOS
        or budget exhaustion (mirrors the synchronized engine: the EOS token
        itself is emitted, then the request stops)."""
        st = self._slots[slot]
        self._record_token(st.uid, token)
        st.n_emitted += 1
        if token == st.gen.eos_token or st.n_emitted >= st.gen.max_new_tokens:
            self._retire(slot)

    def _step(self) -> bool:
        """One scheduler iteration: admit waiting requests into free slots,
        then run one batched decode step over all active slots. Returns True
        while there is (or may be) work left."""
        self._admit()
        if not self._active.any():
            return bool(self._queue)
        with self.tracer.span("decode", batch=int(self._active.sum())), \
                jax.profiler.TraceAnnotation("engine_decode"):
            logits, greedy, self._cache = self._decode(
                self.params, jnp.asarray(self._last), self._cache,
                jnp.asarray(self._active), self._stacked_policy())
        self.decode_steps += 1
        greedy_np = np.asarray(greedy)
        need_sampling = any(st is not None and st.gen.temperature > 0
                            for st in self._slots)
        logits_np = np.asarray(logits) if need_sampling else None
        for slot in range(self.n_slots):
            st = self._slots[slot]
            if st is None:
                continue
            if st.gen.temperature > 0:
                key = jax.random.fold_in(
                    jax.random.fold_in(jax.random.PRNGKey(st.gen.seed),
                                       st.uid), st.n_emitted)
                tok = int(jax.random.categorical(
                    key, jnp.asarray(logits_np[slot]) / st.gen.temperature))
            else:
                tok = int(greedy_np[slot])
            self._last[slot, 0] = tok
            self._emit(slot, tok)
        return True

    def reset_stats(self):
        """Zero the scheduler statistics (after a warmup run, say). Trace
        counters are deliberately kept: warmup compiles are still traces."""
        self.n_admitted = self.n_retired = 0
        self.max_concurrency = 0
        self.decode_steps = 0

    # -- observability hooks (EngineBase) -------------------------------

    def _trace_count(self) -> int:
        return self.prefill_traces + self.decode_traces

    def _device_metrics(self):
        if isinstance(self._cache, dict):
            return self._cache.get("metrics")
        return None

    def _metrics_hook(self, snap) -> None:
        snap.gauge("repro_engine_slots", float(self.n_slots))
        snap.gauge("repro_engine_free_slots", float(self.free_slots))
        snap.counter("repro_engine_decode_steps_total",
                     float(self.decode_steps))
        snap.counter("repro_requests_admitted_total", float(self.n_admitted))
        snap.counter("repro_requests_retired_total", float(self.n_retired))

    @property
    def overflow_pairs(self) -> int:
        """Total token-expert pairs silently dropped by capacity overflow
        since engine construction (0 under ``exact_moe`` on the dispatch
        path; a setp-backed engine now also counts its psum'd device-level
        and local-expert overflow, which exact_moe does NOT pin). The
        counter rides in the decode cache, so reading it costs one scalar
        transfer — no per-step sync."""
        m = self._device_metrics()
        if m is not None:
            return int(m.overflow_pairs)
        if isinstance(self._cache, dict) and "moe_overflow" in self._cache:
            return int(dict.__getitem__(self._cache, "moe_overflow"))
        return 0

    @property
    def free_slots(self) -> int:
        return int(self.n_slots - self._active.sum())

    @property
    def queued(self) -> int:
        return len(self._queue)
