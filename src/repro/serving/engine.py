"""Batched serving engine: one batched prefill + synchronized decode loop,
with the DualSparse-MoE inference system (paper §4) enabled through the
model's DistContext (2T-Drop, load-aware thresholds under EP).

The decode cache carries a single absolute position shared by the batch, so
the engine serves *synchronized batches*: requests are grouped to a common
(padded) prompt length, prefilled in one jitted call, then decoded together
— the exact setting of the paper's efficiency evaluation (fixed 500-token
prompts, 100 output tokens, §5.3.2). Per-request early EOS just stops
collecting tokens for that request.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as M
from ..models.transformer import DistContext


@dataclasses.dataclass
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 => greedy
    eos_token: int = -1               # -1 => never stop early
    seed: int = 0


@dataclasses.dataclass
class Result:
    uid: int
    tokens: List[int]
    prefill_s: float = 0.0
    decode_s: float = 0.0


class ServingEngine:
    """Synchronized-batch engine around jitted prefill/serve steps."""

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 8,
                 max_prompt_len: int = 512, max_new_tokens: int = 128,
                 window: int = 0, pad_token: int = 0,
                 dist: Optional[DistContext] = None):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.window = window
        self.pad_token = pad_token
        ctx = M.context_len_for(cfg, max_prompt_len, max_new_tokens)
        self.context_len = ctx
        self._prefill = jax.jit(
            M.make_prefill_step(cfg, cache_len=ctx, window=window, dist=dist))
        self._serve = jax.jit(M.make_serve_step(cfg, window=window, dist=dist))
        self.max_prompt_len = max_prompt_len

    def _make_batch(self, prompts: List[np.ndarray]) -> Dict[str, jax.Array]:
        """Right-align (left-pad) prompts to the common max length so every
        real token sits at the end — causal attention then gives each request
        a correct suffix context (pads influence only via their K/V, which we
        accept for pad-light batches; equal-length prompts are exact)."""
        L = max(len(p) for p in prompts)
        toks = np.full((len(prompts), L), self.pad_token, np.int32)
        for i, p in enumerate(prompts):
            toks[i, L - len(p):] = p
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "vision":
            batch["frontend"] = jnp.zeros(
                (len(prompts), self.cfg.n_frontend_tokens, self.cfg.d_model))
        if self.cfg.frontend == "audio":
            batch["audio_embeds"] = jnp.zeros(
                (len(prompts), self.cfg.n_frontend_tokens, self.cfg.d_model))
        return batch

    def generate(self, prompts: List[np.ndarray],
                 gen: GenerationConfig) -> List[Result]:
        """Serve a batch of prompts; returns one Result per prompt, in order.
        Oversized batches are split into engine-sized chunks."""
        out: List[Result] = []
        for i in range(0, len(prompts), self.batch_size):
            out.extend(self._generate_chunk(prompts[i:i + self.batch_size],
                                            gen))
        return out

    def _generate_chunk(self, prompts, gen: GenerationConfig) -> List[Result]:
        B = len(prompts)
        batch = self._make_batch(prompts)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0
        results = [Result(uid=i, tokens=[]) for i in range(B)]
        last = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        done = np.zeros(B, bool)
        t0 = time.perf_counter()
        for step in range(gen.max_new_tokens):
            for i in range(B):
                if not done[i]:
                    results[i].tokens.append(int(last[i, 0]))
                    if int(last[i, 0]) == gen.eos_token:
                        done[i] = True
            if done.all():
                break
            logits, cache = self._serve(self.params, last, cache)
            if gen.temperature > 0:
                key = jax.random.fold_in(jax.random.PRNGKey(gen.seed), step)
                last = jax.random.categorical(
                    key, logits[:, -1] / gen.temperature)[:, None].astype(jnp.int32)
            else:
                last = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        t_decode = time.perf_counter() - t0
        for r in results:
            r.prefill_s = t_prefill
            r.decode_s = t_decode
        return results
