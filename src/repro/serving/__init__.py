from .api import (Engine, EngineBase, GenerationConfig, Request,
                  Result)  # noqa: F401
from .engine import (ContinuousBatchingEngine, ServingEngine,
                     exact_moe_dist, merge_policy_override)  # noqa: F401
from .paged import PagedEngine, PageAllocator  # noqa: F401
