from .engine import (ContinuousBatchingEngine, GenerationConfig, Result,
                     ServingEngine, exact_moe_dist,
                     merge_policy_override)  # noqa: F401
