from .engine import ServingEngine, GenerationConfig  # noqa: F401
