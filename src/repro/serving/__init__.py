from .engine import (ContinuousBatchingEngine, GenerationConfig, Result,
                     ServingEngine, exact_moe_dist)  # noqa: F401
