"""Pytree checkpointing: npz shards + a JSON treedef manifest.

No orbax/flax in the container — this is a small, robust, dependency-free
equivalent. Arrays are gathered to host; large leaves are sharded across
multiple npz files (``max_shard_bytes``) so checkpoints of multi-GB models
stream without a single giant allocation.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    max_shard_bytes: int = 1 << 30) -> str:
    """Write tree to ``{ckpt_dir}/step_{step}/`` and return that path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    leaves, _ = _flatten_with_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": {}, "shards": []}
    shard: Dict[str, np.ndarray] = {}
    shard_bytes = 0
    shard_id = 0

    def flush():
        nonlocal shard, shard_bytes, shard_id
        if not shard:
            return
        fn = f"shard_{shard_id:04d}.npz"
        np.savez(os.path.join(path, fn), **shard)
        manifest["shards"].append(fn)
        shard = {}
        shard_bytes = 0
        shard_id += 1

    for key, leaf in leaves.items():
        arr = np.asarray(jax.device_get(leaf))
        safe = re.sub(r"[^A-Za-z0-9_./\[\]-]", "_", key)
        manifest["leaves"][key] = {
            "shard": shard_id, "name": safe,
            "dtype": str(arr.dtype), "shape": list(arr.shape),
        }
        shard[safe] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= max_shard_bytes:
            flush()
    flush()
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def restore_checkpoint(ckpt_dir: str, target: Any,
                       step: Optional[int] = None) -> Any:
    """Restore into the structure of ``target`` (shape/dtype checked)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    shards = [np.load(os.path.join(path, fn), allow_pickle=False)
              for fn in manifest["shards"]]
    leaves, treedef = _flatten_with_paths(target)
    restored = {}
    for key, spec in manifest["leaves"].items():
        arr = shards[spec["shard"]][spec["name"]]
        restored[key] = arr
    out_leaves = []
    for key, tgt in leaves.items():
        if key not in restored:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = restored[key]
        if list(arr.shape) != list(np.shape(tgt)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(tgt)}")
        out_leaves.append(arr.astype(tgt.dtype) if hasattr(tgt, "dtype")
                          else arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), out_leaves)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None
