"""Finding/severity/baseline model for the ``repro.lint`` pass suite.

A finding is one violation of a structural invariant, attributed to the
(pass, code, entry) triple whose string form — the *fingerprint* — is what
the baseline file suppresses. Fingerprints deliberately exclude messages
and numbers so a suppression survives cosmetic drift but a genuinely new
(pass, entry) pairing always surfaces.
"""
from __future__ import annotations

import dataclasses
import enum
import fnmatch
import json
from pathlib import Path
from typing import Dict, List, Optional


class Severity(enum.IntEnum):
    INFO = 0
    WARNING = 1
    ERROR = 2

    def __str__(self) -> str:          # "ERROR", not "Severity.ERROR"
        return self.name


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_name: str                     # e.g. "pallas-vmem"
    code: str                          # e.g. "vmem-budget"
    severity: Severity
    entry: str                         # registry entry name ("" for global)
    message: str
    detail: str = ""

    @property
    def fingerprint(self) -> str:
        return f"{self.pass_name}:{self.code}:{self.entry}"

    def render(self) -> str:
        loc = self.entry or "<global>"
        s = f"{self.severity}: [{self.pass_name}:{self.code}] {loc}: " \
            f"{self.message}"
        if self.detail:
            s += f"\n    {self.detail}"
        return s


@dataclasses.dataclass
class Baseline:
    """Checked-in known-findings file (``lint_baseline.json``).

    ``suppressions``: list of ``{"fingerprint": <glob>, "reason": str}`` —
    fnmatch globs over finding fingerprints. ``hbm_bytes``: per-entry HBM
    estimate the hbm-bytes pass regresses against (written by
    ``--update-baselines``)."""
    suppressions: List[Dict[str, str]] = dataclasses.field(
        default_factory=list)
    hbm_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    path: Optional[Path] = None

    @classmethod
    def load(cls, path) -> "Baseline":
        path = Path(path)
        if not path.exists():
            return cls(path=path)
        raw = json.loads(path.read_text())
        return cls(suppressions=list(raw.get("suppressions", [])),
                   hbm_bytes=dict(raw.get("hbm_bytes", {})),
                   path=path)

    def save(self, path=None) -> None:
        path = Path(path or self.path)
        path.write_text(json.dumps(
            {"suppressions": self.suppressions,
             "hbm_bytes": {k: self.hbm_bytes[k]
                           for k in sorted(self.hbm_bytes)}},
            indent=2) + "\n")

    def suppression_for(self, finding: Finding) -> Optional[str]:
        """The reason string of the first matching suppression, else None."""
        for s in self.suppressions:
            if fnmatch.fnmatchcase(finding.fingerprint,
                                   s.get("fingerprint", "")):
                return s.get("reason", "(no reason given)")
        return None
