"""Pallas-spec passes: VMEM budget, MXU tile alignment, grid coverage.

These run on the ``KernelSpec`` objects the kernel launches themselves
derive their geometry from (``kernels.specs``) — pure arithmetic on static
shapes, so they need neither a TPU nor a trace. Hardware constants follow
the TPU generation targeted by the kernels: ~16 MB VMEM per core, 128x128
MXU, (sublane x 128-lane) min tile with dtype-dependent sublane counts.
"""
from __future__ import annotations

from typing import List

import numpy as np

from ..kernels.specs import KernelSpec
from .findings import Finding, Severity

VMEM_BUDGET_BYTES = 16 * 1024 * 1024
SMEM_BUDGET_BYTES = 1024 * 1024
LANE = 128

# second-to-last-dim multiple for the packed min tile, by dtype itemsize
_SUBLANE_BY_ITEMSIZE = {4: 8, 2: 16, 1: 32}


def check_vmem_footprint(spec: KernelSpec, entry: str,
                         budget: int = VMEM_BUDGET_BYTES) -> List[Finding]:
    """Static VMEM working set vs the per-core budget, computed from the
    spec's residency model (``KernelSpec.vmem_bytes``): streamed vmem
    blocks double-buffered, resident blocks and scratch counted once,
    SMEM/ANY-space blocks excluded — their cost shows up as the explicit
    staging scratch the kernel declares. Entry meta ``vmem_budget``
    overrides the default 16 MB."""
    total = spec.vmem_bytes()
    if total > budget:
        vmem_blocks = spec.blocks_of_space("vmem") or spec.blocks
        worst = max(vmem_blocks, key=lambda b: b.nbytes)
        return [Finding(
            "pallas-vmem", "vmem-budget", Severity.ERROR, entry,
            f"{spec.name}: estimated VMEM working set "
            f"{total / 2**20:.1f} MB exceeds the {budget / 2**20:.0f} MB "
            f"budget",
            f"largest block: {worst.name} {worst.shape} {worst.dtype} "
            f"({worst.nbytes / 2**20:.1f} MB) — shrink block_c/block_f or "
            f"move whole-array operands to ANY memory with explicit DMA "
            f"(space='any' + staging scratch, as the streamed fused "
            f"pipeline does)")]
    if total > 0.8 * budget:
        return [Finding(
            "pallas-vmem", "vmem-near-budget", Severity.WARNING, entry,
            f"{spec.name}: estimated VMEM {total / 2**20:.1f} MB is within "
            f"20% of the {budget / 2**20:.0f} MB budget")]
    return []


def check_smem_footprint(spec: KernelSpec, entry: str,
                         budget: int = SMEM_BUDGET_BYTES) -> List[Finding]:
    """Scalar-memory working set (``space='smem'`` blocks — the
    scalar-prefetch pair maps) vs the per-core SMEM budget. SMEM is tiny
    compared to VMEM, so a map that grows with T*K must be checked at
    prefill scale: the mode-grouped pair layout (T*top_k entries) fits
    where the raw sub-pair layout (T*top_k*P) would not."""
    total = spec.smem_bytes()
    if total > budget:
        worst = max(spec.blocks_of_space("smem"), key=lambda b: b.nbytes)
        return [Finding(
            "pallas-smem", "smem-budget", Severity.ERROR, entry,
            f"{spec.name}: estimated SMEM working set "
            f"{total / 2**10:.0f} KB exceeds the {budget / 2**10:.0f} KB "
            f"budget",
            f"largest map: {worst.name} {worst.shape} {worst.dtype} — "
            f"shrink the per-pair maps (mode-grouped layout) or tile them")]
    if total > 0.8 * budget:
        return [Finding(
            "pallas-smem", "smem-near-budget", Severity.WARNING, entry,
            f"{spec.name}: estimated SMEM {total / 2**10:.0f} KB is within "
            f"20% of the {budget / 2**10:.0f} KB budget")]
    return []


def check_dma_streaming(spec: KernelSpec, entry: str) -> List[Finding]:
    """ANY-space blocks are reachable only through explicit DMA, so the
    spec must declare staging multiplicity: an input with
    ``dma_buffers == 0`` cannot be read at all (ERROR), a single-buffered
    input serializes every gather behind compute (WARNING — the whole
    point of streaming is overlapping the next tile's copy), and outputs
    need at least one staging buffer for the write-back path."""
    out: List[Finding] = []
    for b in spec.blocks_of_space("any"):
        if b.dma_buffers < 1:
            out.append(Finding(
                "pallas-dma", "any-unreachable", Severity.ERROR, entry,
                f"{spec.name}.{b.name}: ANY-space {b.kind} block declares "
                f"no DMA staging buffers",
                "a TPU kernel cannot touch ANY/HBM memory directly — give "
                "the block dma_buffers >= 1 and a matching VMEM staging "
                "scratch"))
        elif b.kind == "in" and b.dma_buffers < 2:
            out.append(Finding(
                "pallas-dma", "single-buffered-input", Severity.WARNING,
                entry,
                f"{spec.name}.{b.name}: ANY-space input is single-buffered "
                f"(dma_buffers={b.dma_buffers})",
                "double-buffer the gather (dma_buffers=2) so the next "
                "tile's HBM->VMEM copy overlaps the current tile's "
                "compute"))
    return out


def _full_dim_values(spec: KernelSpec):
    """Dim sizes that equal a whole logical/padded array dimension — a
    block spanning the full axis cannot be aligned further, the hardware
    pads it to the min tile (wasteful but correct -> INFO, not ERROR)."""
    m = spec.meta
    vals = {m.get(k) for k in ("d", "fp", "Cp", "T", "capacity", "f", "C",
                               "n_pairs_padded", "E")}
    vals.discard(None)
    return vals


def check_mxu_alignment(spec: KernelSpec, entry: str) -> List[Finding]:
    """Last dim % 128 (lane) and second-to-last % sublane(dtype) on every
    matrix block (control blocks and 1-d blocks are exempt). A misaligned
    dim that spans its full logical axis downgrades to INFO — the MXU pads
    it; a misaligned *tile choice* (e.g. block_f=100) is an ERROR because
    every grid step then pays a partial-tile penalty by construction."""
    out: List[Finding] = []
    full = _full_dim_values(spec)
    for b in spec.blocks:
        # SMEM maps are scalar data and ANY blocks are touched by row DMA,
        # not fed to the MXU — only vmem-resident matrix tiles align
        if b.control or b.space != "vmem" or len(b.shape) < 2:
            continue
        last, sub = b.shape[-1], b.shape[-2]
        sublane = _SUBLANE_BY_ITEMSIZE.get(np.dtype(b.dtype).itemsize, 8)
        if last % LANE:
            sev = Severity.INFO if last in full else Severity.ERROR
            out.append(Finding(
                "pallas-mxu", "lane-misaligned", sev, entry,
                f"{spec.name}.{b.name}: last dim {last} % {LANE} != 0",
                "full-axis block; hardware pads the lane dim" if sev ==
                Severity.INFO else
                "pick a block size that is a multiple of 128 lanes"))
        if sub % sublane:
            sev = Severity.INFO if sub in full else Severity.ERROR
            out.append(Finding(
                "pallas-mxu", "sublane-misaligned", sev, entry,
                f"{spec.name}.{b.name}: dim {sub} % {sublane} != 0 "
                f"({b.dtype} sublane)",
                "full-axis block; hardware pads the sublane dim" if sev ==
                Severity.INFO else
                f"pick a block size that is a multiple of {sublane} for "
                f"{b.dtype}"))
    return out


def check_grid_coverage(spec: KernelSpec, entry: str) -> List[Finding]:
    """Cross-check the grid against the resolved geometry meta: every
    logical row/neuron must be covered exactly once, ragged ``f % block_f``
    edges must stay inside one trailing block, and the minor-half boundary
    must land inside the virtual width."""
    out: List[Finding] = []
    m = spec.meta

    def err(code, msg, detail=""):
        out.append(Finding("pallas-grid", code, Severity.ERROR, entry,
                           f"{spec.name}: {msg}", detail))

    block_c, block_f = m.get("block_c"), m.get("block_f")
    Cp, fp = m.get("Cp"), m.get("fp")
    pad_c, pad_f = m.get("pad_c", 0), m.get("pad_f", 0)
    p = m.get("p_factor", 1)
    C, f = m.get("C"), m.get("f")
    if None in (block_c, block_f, Cp, fp, C, f):
        err("meta-incomplete", "spec meta lacks resolved geometry keys")
        return out
    if pad_c >= block_c or pad_f >= block_f:
        err("overpadded", f"padding (pad_c={pad_c}, pad_f={pad_f}) reaches "
            f"a full block — a whole grid step would compute only padding")
    if Cp % block_c or Cp != C + pad_c or Cp < C:
        err("row-coverage", f"Cp={Cp} does not tile C={C} by "
            f"block_c={block_c}")
    if fp % block_f or fp != f + pad_f or fp < f:
        err("neuron-coverage", f"fp={fp} does not tile f={f} by "
            f"block_f={block_f}")
    want_grid = (m.get("E"), Cp // block_c, p * (fp // block_f))
    if tuple(spec.grid) != tuple(want_grid):
        err("grid-mismatch", f"grid {tuple(spec.grid)} != expected "
            f"{want_grid} from (E, Cp/block_c, p_factor*fp/block_f)",
            "a launch deriving its grid elsewhere than the spec would "
            "silently skip or duplicate tiles")
    nms = m.get("n_minor_start")
    virtual = fp * p
    if nms is None or not (0 <= nms <= virtual):
        err("minor-boundary", f"n_minor_start={nms} outside the virtual "
            f"neuron width [0, {virtual}]",
            "MAJOR-only rows would skip the wrong tiles")
    return out
