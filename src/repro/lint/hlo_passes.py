"""HLO-level passes: forbidden-buffer shapes, collective budgets, HBM bytes.

These generalize the one-off assertion PR 6 ran inside
``benchmarks/bench_moe_pipeline.py`` (count (E, capacity, d) shapes in the
fused path's HLO) into reusable checks over any registry entry that lowers
to HLO text. Everything parses the compiled module with
``launch.hlo_analysis`` — no execution.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from ..launch import hlo_analysis as ha
from .findings import Finding, Severity

# factor by which the HBM estimate may grow over its checked-in baseline
# before the lint errors (parser jitter across jaxlib versions stays well
# under this; a re-materialized capacity buffer does not)
HBM_TOLERANCE = 1.5


def capacity_buffer_count(hlo: str, n_groups: int, capacity: int, d: int,
                          *, block_c: int = 128) -> int:
    """Instructions materializing an (E, capacity, d) dispatch buffer —
    exact or padded to the kernel's row-block multiple. The fused pipeline
    must produce ZERO of these; the buffer path produces many. This is the
    single source of truth for both the lint pass and
    ``benchmarks/bench_moe_pipeline.py``'s CI gate."""
    bc = min(block_c, capacity)
    cap_padded = (capacity + bc - 1) // bc * bc
    n = ha.count_shape_instructions(hlo, (n_groups, capacity, d))
    if cap_padded != capacity:
        n += ha.count_shape_instructions(hlo, (n_groups, cap_padded, d))
    return n


def check_forbidden_shapes(hlo: str, entry: str,
                           shapes: Sequence[Tuple[int, ...]],
                           dtype: Optional[str] = None) -> List[Finding]:
    """ERROR for every instruction whose result materializes one of the
    forbidden dims tuples (entry meta ``forbid_shapes``)."""
    out: List[Finding] = []
    for dims in shapes:
        n = ha.count_shape_instructions(hlo, dims, dtype=dtype)
        if n:
            out.append(Finding(
                "hlo-capacity-buffer", "forbidden-shape", Severity.ERROR,
                entry, f"{n} instruction(s) materialize a "
                f"{tuple(int(x) for x in dims)} buffer the fused path "
                f"exists to eliminate",
                "the dispatch gather / unpermute read-back leaked back "
                "into this entry point — check fused_pipeline plumbing"))
    return out


def check_required_shapes(hlo: str, entry: str,
                          shapes: Sequence[Tuple[int, ...]]) -> List[Finding]:
    """Converse guard (entry meta ``require_shapes``): the buffer-path
    oracle must still materialize its capacity buffer — zero means the
    forbidden-shape gate's target moved and the fused check is vacuous."""
    out: List[Finding] = []
    for dims in shapes:
        if ha.count_shape_instructions(hlo, dims) == 0:
            out.append(Finding(
                "hlo-capacity-buffer", "expected-shape-missing",
                Severity.ERROR, entry,
                f"no instruction materializes the expected "
                f"{tuple(int(x) for x in dims)} buffer",
                "the capacity-buffer gate is comparing against nothing — "
                "update the entry geometry"))
    return out


def check_collective_budget(hlo: str, entry: str,
                            budget: Dict[str, int]) -> List[Finding]:
    """Per-entry collective-op budgets for shard_map paths (entry meta
    ``collective_budget``: HLO kind -> max instruction count, e.g. the
    S-ETP invariant of exactly one dispatch + one return all-to-all).
    Kinds not listed are unconstrained."""
    stats = ha.collect_collectives(hlo)
    out: List[Finding] = []
    for kind, limit in sorted(budget.items()):
        got = int(stats.count_by_kind.get(kind, 0))
        if got > limit:
            out.append(Finding(
                "hlo-collectives", f"budget-{kind}", Severity.ERROR, entry,
                f"{got}x '{kind}' exceeds this entry's budget of {limit}",
                "an extra collective per MoE layer multiplies across the "
                "stack — fold it into the existing psum/AlltoAll or raise "
                "the budget deliberately"))
    return out


def check_hbm_bytes(hlo: str, entry: str,
                    baseline_bytes: Optional[float]) -> List[Finding]:
    """Regress the parsed HBM-traffic estimate against the checked-in
    baseline (``lint_baseline.json`` ``hbm_bytes``); WARNING when no
    baseline exists yet (run ``--update-baselines``)."""
    actual = ha.analyze_hlo(hlo).hbm_bytes
    if baseline_bytes is None:
        return [Finding(
            "hlo-hbm", "no-baseline", Severity.WARNING, entry,
            f"no HBM baseline recorded (current estimate: "
            f"{actual / 1e6:.2f} MB)",
            "run `python -m repro.lint --update-baselines` and commit "
            "lint_baseline.json")]
    if actual > baseline_bytes * HBM_TOLERANCE:
        return [Finding(
            "hlo-hbm", "regression", Severity.ERROR, entry,
            f"HBM estimate {actual / 1e6:.2f} MB exceeds baseline "
            f"{baseline_bytes / 1e6:.2f} MB by more than "
            f"{HBM_TOLERANCE:.1f}x",
            "a layout/materialization regression — or a deliberate change "
            "that should refresh the baseline with --update-baselines")]
    if actual * HBM_TOLERANCE < baseline_bytes:
        return [Finding(
            "hlo-hbm", "improved", Severity.INFO, entry,
            f"HBM estimate {actual / 1e6:.2f} MB is well below baseline "
            f"{baseline_bytes / 1e6:.2f} MB — consider refreshing the "
            f"baseline to lock in the win")]
    return []


def check_hbm_ordering(hlo_by_entry: Dict[str, str], entry: str,
                       less_than_entry: str) -> List[Finding]:
    """Relative invariant (entry meta ``hbm_less_than``): this entry's HBM
    estimate must stay below the named entry's — e.g. fused pipeline <
    capacity-buffer oracle on identical shapes."""
    this_hlo = hlo_by_entry.get(entry)
    other_hlo = hlo_by_entry.get(less_than_entry)
    if this_hlo is None or other_hlo is None:
        return []
    a = ha.analyze_hlo(this_hlo).hbm_bytes
    b = ha.analyze_hlo(other_hlo).hbm_bytes
    if a >= b:
        return [Finding(
            "hlo-hbm", "ordering", Severity.ERROR, entry,
            f"HBM estimate {a / 1e6:.2f} MB is not below "
            f"{less_than_entry!r}'s {b / 1e6:.2f} MB",
            "the fused path lost its traffic advantage over the buffer "
            "oracle")]
    return []
