"""Jaxpr-level passes: dtype promotion, host syncs, policy retrace hazards.

All three inspect traces, never run computation, so they are cheap and
deterministic. The shared equation walker recurses into every sub-jaxpr a
higher-order primitive carries (pjit, scan, while, cond, shard_map,
pallas_call, custom_vjp, ...) by structurally scanning ``eqn.params`` for
Jaxpr/ClosedJaxpr values — robust to new primitives without a registry.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import core as jcore

from .findings import Finding, Severity

# avals with these dtype names are silent-upcast hazards: nothing in this
# repo wants f64/c128 math, so their presence means a Python scalar or an
# x64-context promotion leaked into a hot path. Integers are NOT flagged
# (i64 shape math is benign and jit-invisible).
_BAD_DTYPES = ("float64", "complex128")

# primitives that force a host round-trip / side channel inside a step
_HOST_PRIMS = ("pure_callback", "io_callback", "debug_callback", "callback",
               "infeed", "outfeed")


def _subjaxprs(params) -> Iterator[jcore.Jaxpr]:
    """Yield every Jaxpr found structurally inside an eqn's params."""
    for v in params.values():
        stack = [v]
        while stack:
            x = stack.pop()
            if isinstance(x, jcore.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jcore.Jaxpr):
                yield x
            elif isinstance(x, (tuple, list)):
                stack.extend(x)
            elif isinstance(x, dict):
                stack.extend(x.values())


def iter_eqns(jaxpr) -> Iterator[jcore.JaxprEqn]:
    """Depth-first over all equations, sub-jaxprs included."""
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _subjaxprs(eqn.params):
            yield from iter_eqns(sub)


def _aval_dtype_name(aval) -> Optional[str]:
    dt = getattr(aval, "dtype", None)
    return None if dt is None else np.dtype(dt).name


def check_dtype_promotion(jaxpr, entry: str) -> List[Finding]:
    """Flag f64/c128 result avals and explicit converts into them.

    Run the traced function under ``jax.experimental.enable_x64`` when
    probing for *latent* promotions: code that is f32-explicit stays clean,
    code that leans on weak-type defaults lights up."""
    out: List[Finding] = []
    seen = set()
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            name = _aval_dtype_name(var.aval)
            if name in _BAD_DTYPES:
                key = (eqn.primitive.name, name)
                if key in seen:
                    continue
                seen.add(key)
                out.append(Finding(
                    "jaxpr-dtype", "f64-upcast", Severity.ERROR, entry,
                    f"'{eqn.primitive.name}' produces {name} "
                    f"{getattr(var.aval, 'shape', ())}",
                    "pin the computation to f32 explicitly (astype / "
                    "dtype=) — under jax_enable_x64 this silently doubles "
                    "memory traffic and falls off the MXU fast path"))
        if eqn.primitive.name == "convert_element_type":
            new = np.dtype(eqn.params.get("new_dtype", np.float32)).name
            src = _aval_dtype_name(eqn.invars[0].aval) \
                if eqn.invars else None
            if new in _BAD_DTYPES and src not in _BAD_DTYPES:
                key = ("convert", src, new)
                if key not in seen:
                    seen.add(key)
                    out.append(Finding(
                        "jaxpr-dtype", "explicit-upcast", Severity.ERROR,
                        entry, f"explicit convert {src} -> {new}",
                        "remove the upcast or make it f32"))
    return out


def check_host_sync(jaxpr, entry: str) -> List[Finding]:
    """Flag host-callback/transfer primitives inside a jitted entry point:
    each one serializes the device stream against Python."""
    out: List[Finding] = []
    counts = {}
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name in _HOST_PRIMS:
            counts[eqn.primitive.name] = counts.get(eqn.primitive.name,
                                                    0) + 1
    for prim, n in sorted(counts.items()):
        sev = Severity.WARNING if prim == "debug_callback" else Severity.ERROR
        out.append(Finding(
            "jaxpr-hostsync", prim, sev, entry,
            f"{n}x '{prim}' inside the traced entry point",
            "host callbacks stall the accelerator pipeline every step; "
            "strip debug prints / move the side channel out of the jit"))
    return out


def check_traced_leaves(jaxpr, entry: str, leaves) -> List[Finding]:
    """Indirection arrays (page tables and friends) must enter a jitted
    step as TRACED arguments. ``leaves`` is a list of (shape, dtype-name)
    specs from the entry's meta; each must match an invar of the traced
    jaxpr. A spec matching only a captured CONSTANT is the retrace hazard
    this pass exists for: the constant's VALUE is baked into the
    executable, so every allocator churn (page reuse, prefix hit,
    eviction) silently recompiles the step."""
    out: List[Finding] = []
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        consts = [(tuple(np.shape(c)), np.dtype(
            getattr(c, "dtype", type(c))).name) for c in jaxpr.consts]
        jaxpr = jaxpr.jaxpr
    else:
        consts = [(tuple(v.aval.shape), _aval_dtype_name(v.aval))
                  for v in jaxpr.constvars]
    invars = [(tuple(v.aval.shape), _aval_dtype_name(v.aval))
              for v in jaxpr.invars]
    for spec in leaves:
        shape, dtype = tuple(spec[0]), str(spec[1])
        if (shape, dtype) in invars:
            continue
        if (shape, dtype) in consts:
            out.append(Finding(
                "jaxpr-traced-leaves", "leaf-captured-constant",
                Severity.ERROR, entry,
                f"{dtype}{list(shape)} leaf is a captured constant, not a "
                f"traced argument",
                "pass the array into the jitted step as an argument — as a "
                "closure constant its value hashes into the jit cache key "
                "and every page-table update recompiles"))
        else:
            out.append(Finding(
                "jaxpr-traced-leaves", "leaf-missing", Severity.ERROR,
                entry, f"no {dtype}{list(shape)} invar in the traced step",
                "the entry's traced_leaves meta no longer matches the "
                "step's signature — update the registry entry"))
    return out


# ---------------------------------------------------------------------------
# Retrace-hazard audit of the SparsityPolicy registry (global pass)
# ---------------------------------------------------------------------------

def check_policy_retrace(policies=None) -> List[Finding]:
    """Cross-check every registered policy's pytree static/traced split.

    Hazards flagged:
      * a static (aux-data) field holding a jax/numpy array — its VALUE is
        hashed into the jit cache key, so every new threshold array
        retraces (and arrays make the aux tuple unhashable under jit);
      * any unhashable static field value (lists, dicts, sets);
      * a ``_dynamic`` name that is not a dataclass field (the flatten
        would raise AttributeError at dispatch time);
      * a dynamic leaf that cannot become a jnp array (it could never ride
        through shard_map / donated buffers).
    """
    if policies is None:
        from ..core.policy import registered_policies
        policies = registered_policies()
    from ..configs.base import DualSparseConfig
    out: List[Finding] = []
    ds = DualSparseConfig()
    for name, cls in sorted(policies.items()):
        entry = f"policy/{name}"
        fields = {f.name for f in dataclasses.fields(cls)}
        dyn = tuple(getattr(cls, "_pytree_dynamic", cls._dynamic))
        static = tuple(getattr(cls, "_pytree_static",
                               tuple(f for f in fields if f not in dyn)))
        for d in dyn:
            if d not in fields:
                out.append(Finding(
                    "policy-retrace", "dynamic-not-a-field", Severity.ERROR,
                    entry, f"_dynamic lists {d!r} but the dataclass has no "
                    f"such field"))
        try:
            pol = cls.from_config(ds)
        except Exception as e:  # noqa: BLE001 — report, don't crash the lint
            out.append(Finding(
                "policy-retrace", "from-config-failed", Severity.ERROR,
                entry, f"from_config(DualSparseConfig()) raised "
                f"{type(e).__name__}: {e}"))
            continue
        aux_vals = []
        for s in static:
            v = getattr(pol, s, None)
            if isinstance(v, (jnp.ndarray, np.ndarray)):
                out.append(Finding(
                    "policy-retrace", "traced-value-hashed", Severity.ERROR,
                    entry, f"static field {s!r} holds an array — its value "
                    f"becomes part of the jit cache key",
                    "move the field into _dynamic so it is a traced leaf"))
                continue
            aux_vals.append((s, v))
        try:
            hash(tuple(v for _, v in aux_vals))
        except TypeError:
            bad = [s for s, v in aux_vals
                   if not _hashable(v)]
            out.append(Finding(
                "policy-retrace", "unhashable-static", Severity.ERROR,
                entry, f"static field(s) {bad} are unhashable — the policy "
                f"cannot be a jit argument at all",
                "use tuples/frozen values for static structure, or list "
                "the field in _dynamic"))
        leaves, _ = jax.tree_util.tree_flatten(pol)
        if len(leaves) != len(dyn):
            out.append(Finding(
                "policy-retrace", "leaf-count-mismatch", Severity.ERROR,
                entry, f"tree_flatten yields {len(leaves)} leaves but "
                f"_dynamic lists {len(dyn)} fields"))
        for fname, leaf in zip(dyn, leaves):
            try:
                jnp.asarray(leaf)
            except Exception:  # noqa: BLE001
                out.append(Finding(
                    "policy-retrace", "untraceable-leaf", Severity.ERROR,
                    entry, f"dynamic field {fname!r} = {leaf!r} cannot "
                    f"become a jax array"))
    return out


def _hashable(v) -> bool:
    try:
        hash(v)
        return True
    except TypeError:
        return False
