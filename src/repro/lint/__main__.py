"""CLI: ``python -m repro.lint [--ci] [--entries GLOB] [--passes GLOB] ...``

Forces 8 host devices BEFORE importing jax so the shard_map (S-ETP)
entries lower with real collectives for the collective-budget pass; all
other entries are device-count-agnostic.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Static-analysis pass suite over the repo's public "
                    "entry points (jaxpr / HLO / Pallas-spec families).")
    ap.add_argument("--ci", action="store_true",
                    help="full matrix; exit 1 on any non-suppressed ERROR")
    ap.add_argument("--entries", action="append", metavar="GLOB",
                    help="only entries matching GLOB (repeatable), e.g. "
                         "'dispatch/*' or 'kernel/*'")
    ap.add_argument("--passes", action="append", metavar="GLOB",
                    help="only passes matching GLOB (repeatable), e.g. "
                         "'pallas-*'")
    ap.add_argument("--baseline", default=None,
                    help="baseline/suppression file "
                         "(default: ./lint_baseline.json)")
    ap.add_argument("--update-baselines", action="store_true",
                    help="rewrite the baseline's hbm_bytes from this run")
    ap.add_argument("--list", action="store_true", dest="list_entries",
                    help="print the entry matrix and exit")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings on stdout")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="show INFO findings too")
    args = ap.parse_args(argv)

    if "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=8").strip()
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from .registry import build_entries
    from .runner import run_lint

    entries = build_entries()
    if args.list_entries:
        for e in entries:
            print(e.name)
        return 0

    report = run_lint(entries=entries, entry_globs=args.entries,
                      pass_globs=args.passes,
                      baseline_path=args.baseline,
                      update_baselines=args.update_baselines)
    print(report.as_json() if args.json
          else report.render(verbose=args.verbose))
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
