"""The entry-point registry: every public computation surface, traced
across a matrix of representative configs.

An entry owns a lazy ``trace()`` producing ``Artifacts``: a closed jaxpr
(always, for traceable entries), compiled HLO text (when the entry opts
in — compilation costs seconds, tracing milliseconds), and/or static
``KernelSpec`` objects (spec-only entries need no tracing at all). Entry
``meta`` carries the per-entry pass parameters: forbidden buffer shapes,
collective budgets, VMEM budget overrides, the x64-probe flag.

Families (glob-friendly names):
  dispatch/<policy>/T<n>   single-device MoE forward, dispatch path
  pipeline/{buffer,fused}  capacity-buffer oracle vs fused Pallas pipeline
  setp/<policy>            shard_map S-ETP forward (needs >= 2 devices)
  obs/dispatch_metrics/<policy>    metrics-collecting MoE layer forward
  engine/{prefill_insert,decode}   continuous-batching jitted steps
  engine/{chunk_insert,paged_decode,prefix_hit_insert}  paged-KV steps
  calib/{threshold,load_aware}     calibration math probed under x64
  kernel/<name>/<scenario>         production-scale KernelSpecs (no trace)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Artifacts:
    jaxpr: Any = None                 # ClosedJaxpr
    hlo: Optional[str] = None         # compiled module text
    kernel_specs: Tuple = ()          # KernelSpec objects


@dataclasses.dataclass
class LintEntry:
    name: str
    meta: Dict[str, Any]
    _trace: Callable[[], Artifacts]
    _cache: Optional[Artifacts] = None

    def trace(self) -> Artifacts:
        if self._cache is None:
            self._cache = self._trace()
        return self._cache


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _abstract_moe_params(cfg, p: int, *, per_layer_thresholds: bool = False):
    """ShapeDtypeStruct param dict of one prepared MoE layer: partial
    transformation splits each expert's f neurons into p sub-experts."""
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    assert f % p == 0
    params = {
        "wg": _sds((d, E)),
        "w1": _sds((E * p, d, f // p)),
        "w3": _sds((E * p, d, f // p)),
        "w2": _sds((E * p, f // p, d)),
    }
    if per_layer_thresholds:
        params["thresholds"] = _sds((2,))
    return params


def _jaxpr_and_hlo(fn, args, *, want_hlo: bool) -> Artifacts:
    jaxpr = jax.make_jaxpr(fn)(*args)
    hlo = None
    if want_hlo:
        hlo = jax.jit(fn).lower(*args).compile().as_text()
    return Artifacts(jaxpr=jaxpr, hlo=hlo)


# ---------------------------------------------------------------------------
# Entry builders
# ---------------------------------------------------------------------------

def _dispatch_entry(cfg, policy_name: str, T: int, *,
                    want_hlo: bool) -> LintEntry:
    from ..core import moe as moe_mod
    from ..core.policy import make_policy

    kw = {"use_kernel": True} if policy_name in ("2t",) else {}
    policy = make_policy(policy_name, cfg.dualsparse, **kw)
    p = policy.partition_p
    params = _abstract_moe_params(
        cfg, p, per_layer_thresholds=(policy_name == "per_layer"))
    x = _sds((T, cfg.d_model))

    def fn(params, x):
        pairs = policy.route(params, x, cfg)
        return moe_mod.moe_forward_dispatch(
            params, x, cfg, pairs,
            capacity_factor=policy.capacity_factor,
            use_kernel=policy.use_kernel,
            mode_grouped=policy.kernel_mode_grouping,
            fused_pipeline=policy.fused_pipeline)

    return LintEntry(
        name=f"dispatch/{policy_name}/T{T}",
        meta={"x64_probe": False, "hbm_baseline": want_hlo},
        _trace=lambda: _jaxpr_and_hlo(fn, (params, x), want_hlo=want_hlo))


def _pipeline_entries(cfg, T: int) -> List[LintEntry]:
    from ..core import moe as moe_mod
    from ..core.policy import make_policy

    policy = make_policy("2t", cfg.dualsparse, use_kernel=True)
    p = policy.partition_p
    params = _abstract_moe_params(cfg, p)
    x = _sds((T, cfg.d_model))
    # mode-grouped kernel paths group by ORIGINAL expert (same geometry as
    # benchmarks/bench_moe_pipeline.py, whose CI assertion this pass
    # absorbs)
    E = cfg.n_experts
    capacity = moe_mod.capacity_for(T, cfg.top_k, E, policy.capacity_factor)

    def make_fn(fused: bool):
        def fn(params, x):
            pairs = policy.route(params, x, cfg)
            return moe_mod.moe_forward_dispatch(
                params, x, cfg, pairs, capacity=capacity,
                use_kernel=not fused,
                mode_grouped=policy.kernel_mode_grouping,
                fused_pipeline=fused)
        return fn

    d = cfg.d_model
    forbidden = [(E, capacity, d)]
    bc = min(128, capacity)
    cap_padded = (capacity + bc - 1) // bc * bc
    if cap_padded != capacity:
        forbidden.append((E, cap_padded, d))
    buffer_entry = LintEntry(
        name=f"pipeline/buffer/T{T}",
        meta={"hbm_baseline": True, "require_shapes": forbidden[:1]},
        _trace=lambda: _jaxpr_and_hlo(make_fn(False), (params, x),
                                      want_hlo=True))
    fused_entry = LintEntry(
        name=f"pipeline/fused/T{T}",
        meta={"forbid_shapes": forbidden,
              "hbm_less_than": f"pipeline/buffer/T{T}",
              "hbm_baseline": True},
        _trace=lambda: _jaxpr_and_hlo(make_fn(True), (params, x),
                                      want_hlo=True))
    return [buffer_entry, fused_entry]


def _setp_entry(cfg, policy_name: str, n_dev: int) -> LintEntry:
    from ..core.policy import make_policy
    from ..core.setp import setp_moe_forward
    from ..launch.mesh import make_host_mesh

    policy = make_policy(policy_name, cfg.dualsparse)
    p = policy.partition_p
    params = _abstract_moe_params(cfg, p)
    B, S = 2, 8
    x = _sds((B, S, cfg.d_model))
    mesh = make_host_mesh(model=n_dev)

    def fn(params, x):
        return setp_moe_forward(params, x, cfg, mesh, policy=policy,
                                return_overflow=True)

    # the S-ETP invariant: ONE dispatch AlltoAll + ONE return AlltoAll per
    # layer; psums only for overflow (+ the load histogram when the policy
    # needs it); never an all-gather of the token block
    n_psum = 2 + (1 if policy.needs_loads else 0)
    budget = {"all-to-all": 2, "all-reduce": n_psum, "all-gather": 0}
    return LintEntry(
        name=f"setp/{policy_name}",
        meta={"collective_budget": budget, "hbm_baseline": True},
        _trace=lambda: _jaxpr_and_hlo(fn, (params, x), want_hlo=True))


def _engine_entries() -> List[LintEntry]:
    from ..configs import get_config
    from ..models import model as M
    from ..obs import metrics_spec
    from ..serving.engine import ContinuousBatchingEngine

    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    params, _ = M.abstract_params_and_axes(cfg)
    n_slots, lp = 2, 16

    def build(which: str):
        def trace():
            eng = ContinuousBatchingEngine(cfg, params, n_slots=n_slots,
                                           max_prompt_len=lp,
                                           max_new_tokens=8)
            cache = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                eng._cache)
            policy = eng._base_policy
            if which == "prefill_insert":
                fn = eng._prefill_insert.__wrapped__
                args = (params, _sds((1, lp), jnp.int32),
                        _sds((), jnp.int32), _sds((), jnp.int32),
                        cache, policy)
            else:
                fn = eng._decode.__wrapped__
                args = (params, _sds((n_slots, 1), jnp.int32), cache,
                        _sds((n_slots,), jnp.bool_), policy)
            return Artifacts(jaxpr=jax.make_jaxpr(fn)(*args))
        return trace

    # engines default to metrics=True, so both steps trace with the
    # MetricsState seam in the cache. The expert-load histogram leaf must
    # be a traced ARGUMENT (counter values change every step — a captured
    # constant would retrace per decode), and the jaxpr-hostsync pass
    # proves the seam adds no host callbacks to the hot path.
    spec = metrics_spec(cfg, params)
    metrics_leaf = [[list(spec), "int32"]] if spec else []
    return [LintEntry(name=f"engine/{which}",
                      meta={"traced_leaves": metrics_leaf},
                      _trace=build(which))
            for which in ("prefill_insert", "decode")]


def _obs_dispatch_entry(cfg, policy_name: str, T: int, *,
                        want_hlo: bool) -> LintEntry:
    """The metrics-collecting MoE layer forward (``_moe_forward`` with
    ``collect=True``): same routing and dispatch as ``dispatch/<policy>``
    plus the per-layer obs stats dict. The pass set proves the seam costs
    no host syncs and no extra capacity buffers; hbm_baseline tracks its
    (small, int32) memory footprint."""
    from ..core.policy import make_policy
    from ..models import transformer
    from ..models.transformer import DistContext

    kw = {"use_kernel": True} if policy_name in ("2t",) else {}
    policy = make_policy(policy_name, cfg.dualsparse, **kw)
    p = policy.partition_p
    params = _abstract_moe_params(
        cfg, p, per_layer_thresholds=(policy_name == "per_layer"))
    B, S = 2, 32
    x = _sds((B, S, cfg.d_model))
    dist = DistContext(mesh=None, moe_impl="dispatch", policy=policy)

    def fn(params, x):
        y, _, stats = transformer._moe_forward(params, x, cfg, dist,
                                               collect=True)
        return y, stats

    return LintEntry(
        name=f"obs/dispatch_metrics/{policy_name}",
        meta={"x64_probe": False, "hbm_baseline": want_hlo},
        _trace=lambda: _jaxpr_and_hlo(fn, (params, x), want_hlo=want_hlo))


def _paged_engine_entries(*, want_hlo: bool) -> List[LintEntry]:
    """The paged serving engine's jitted steps. All three carry a
    ``traced_leaves`` check on the page-table array: slot->page indirection
    must enter the step as a TRACED argument, never a captured constant —
    a constant page table re-hashes into a new executable on every
    allocator churn (page reuse, prefix hit, eviction), silently
    recompiling per admission."""
    from ..configs import get_config
    from ..models import model as M
    from ..serving.paged import PagedEngine

    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    params, _ = M.abstract_params_and_axes(cfg)
    n_slots, lp, chunk, ps = 2, 16, 8, 4

    def build(which: str, hlo: bool):
        def trace():
            eng = PagedEngine(cfg, params, n_slots=n_slots, page_size=ps,
                              chunk_size=chunk, max_prompt_len=lp,
                              max_new_tokens=8)
            cache = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                eng._cache)
            pt = _sds((n_slots, eng.pages_per_slot), jnp.int32)
            policy = eng._base_policy
            if which == "paged_decode":
                fn = eng._decode.__wrapped__
                args = (params, _sds((n_slots, 1), jnp.int32), cache,
                        _sds((n_slots,), jnp.bool_), pt, policy)
            else:
                # chunk_insert and prefix_hit_insert share ONE jitted step:
                # a prefix hit only changes the traced ``start`` scalar and
                # page-table values, so admission after a hit reuses the
                # cold-path executable — both entries lock that contract.
                fn = eng._chunk_insert.__wrapped__
                args = (params, _sds((1, chunk), jnp.int32),
                        _sds((), jnp.int32), _sds((), jnp.int32),
                        _sds((), jnp.int32), cache, pt, policy)
            return _jaxpr_and_hlo(fn, args, want_hlo=hlo)
        return trace

    pt_shape = [n_slots, -(-(lp + 8) // ps)]
    entries = []
    for which in ("chunk_insert", "paged_decode", "prefix_hit_insert"):
        # prefix_hit_insert shares chunk_insert's executable — skip its
        # (duplicate) compile and keep it as a jaxpr-only contract entry
        hlo = want_hlo and which != "prefix_hit_insert"
        meta = {"traced_leaves": [[pt_shape, "int32"]],
                # single-device serving steps must stay collective-free: an
                # all-gather of the page pool would defeat paging entirely
                "collective_budget": {"all-gather": 0, "all-to-all": 0},
                "hbm_baseline": hlo}
        entries.append(LintEntry(name=f"engine/{which}", meta=meta,
                                 _trace=build(which, hlo)))
    return entries


def _calib_entries(cfg) -> List[LintEntry]:
    """Calibration math, traced under jax_enable_x64: f32-explicit code
    stays clean, weak-type-dependent code lights the dtype pass up. These
    entries justify the f32 pinning in core.drop / core.load_aware."""
    from ..core import drop as drop_mod
    from ..core import load_aware

    def trace_threshold():
        scores = _sds((256, cfg.top_k))
        with jax.experimental.enable_x64():
            def fn(scores):
                t = drop_mod.calibrate_threshold(scores, 0.25)
                rates = drop_mod.threshold_to_drop_rate(
                    scores, [0.05, 0.1, 0.2])
                per_layer = drop_mod.calibrate_per_layer_thresholds(
                    [scores, scores], 0.25)
                return t, rates, per_layer
            return Artifacts(jaxpr=jax.make_jaxpr(fn)(scores))

    def trace_load_aware():
        hist = _sds((cfg.n_experts,), jnp.int32)
        idx = _sds((64, cfg.top_k), jnp.int32)
        with jax.experimental.enable_x64():
            def fn(hist, idx):
                loads = load_aware.device_loads(hist, 2)
                t_dev = load_aware.step_down_thresholds(loads, 0.12)
                tm, tn = load_aware.pair_thresholds(idx, loads, 2, 0.12)
                return t_dev, tm, tn, load_aware.makespan(loads)
            return Artifacts(jaxpr=jax.make_jaxpr(fn)(hist, idx))

    return [
        LintEntry(name="calib/threshold", meta={"x64_probe": True},
                  _trace=trace_threshold),
        LintEntry(name="calib/load_aware", meta={"x64_probe": True},
                  _trace=trace_load_aware),
    ]


def _kernel_spec_entries() -> List[LintEntry]:
    """Production-scale static specs (qwen3-moe-30b-a3b dims, bf16): no
    tracing, pure geometry — the checks a TPU deployment needs before any
    hardware exists in the loop."""
    from ..core.moe import capacity_for
    from ..kernels import (fused_moe_pipeline_kernel_spec,
                           grouped_swiglu_kernel_spec)

    d, f, E, top_k, P = 2048, 768, 128, 8, 2
    fsub = f // P

    def gs_trace():
        cap = capacity_for(4096, top_k * P, E * P, 1.25)
        return Artifacts(kernel_specs=(grouped_swiglu_kernel_spec(
            E, cap, d, fsub, dtype=jnp.bfloat16, p_factor=1),))

    def fused_trace(T, *, d=d, f=fsub, E=E, top_k=top_k):
        # production fused path at P>1 is mode-grouped: ONE pair per
        # (token, original expert), so the scalar-prefetch maps carry
        # T*top_k entries (+ one block of padding) — half the sub-pair
        # layout at P=2, which is what keeps them inside the SMEM budget
        # at prefill scale
        def trace():
            cap = capacity_for(T, top_k * P, E, 2.0)
            n_pairs = T * top_k + 128
            return Artifacts(kernel_specs=(fused_moe_pipeline_kernel_spec(
                T, d, f, E, n_pairs, capacity=cap, dtype=jnp.bfloat16,
                p_factor=P),))
        return trace

    return [
        LintEntry(name="kernel/grouped_swiglu/prod", meta={},
                  _trace=gs_trace),
        LintEntry(name="kernel/fused_pipeline/prod_decode", meta={},
                  _trace=fused_trace(256)),
        # prefill scale is CLEAN since the streamed rewrite: pair maps in
        # scalar-prefetch SMEM, x/out in ANY memory behind double-buffered
        # DMA, so the VMEM working set no longer grows with T (the old
        # resident layout blew the budget here ~6x and was suppressed in
        # lint_baseline.json — the suppression is deleted and a regression
        # test keeps the unstreamed spec failing)
        LintEntry(name="kernel/fused_pipeline/prod_prefill", meta={},
                  _trace=fused_trace(8192)),
        # wide-model prefill: Mixtral-class dims (d=4096, 64 experts,
        # top_k=2) — the acceptance shape for the streamed residency model
        LintEntry(name="kernel/fused_pipeline/prefill_8k_wide", meta={},
                  _trace=fused_trace(8192, d=4096, f=14336 // P, E=64,
                                     top_k=2)),
    ]


# ---------------------------------------------------------------------------
# The matrix
# ---------------------------------------------------------------------------

def build_entries(*, include_hlo: bool = True,
                  include_engine: bool = True) -> List[LintEntry]:
    """The full entry matrix for this machine. S-ETP entries appear only
    when the process sees >= 2 devices (the CLI forces 8 host devices;
    in-process test runs on the single-device default skip them).

    ``include_hlo=False`` keeps every entry jaxpr/spec-only (fast path for
    tests); ``include_engine=False`` skips the two transformer-sized
    traces."""
    from ..configs import get_config

    cfg = get_config("olmoe-lite").reduced()
    entries: List[LintEntry] = []
    for pol in ("none", "1t", "2t", "load_aware", "per_layer"):
        entries.append(_dispatch_entry(cfg, pol, 64,
                                       want_hlo=include_hlo))
    entries.append(_dispatch_entry(cfg, "2t", 256, want_hlo=False))
    entries.append(_obs_dispatch_entry(cfg, "2t", 64,
                                       want_hlo=include_hlo))
    if include_hlo:
        entries.extend(_pipeline_entries(cfg, 64))
    if include_hlo and len(jax.devices()) >= 2:
        n_dev = 4 if len(jax.devices()) % 4 == 0 else 2
        for pol in ("2t", "load_aware"):
            entries.append(_setp_entry(cfg, pol, n_dev))
    if include_engine:
        entries.extend(_engine_entries())
        entries.extend(_paged_engine_entries(want_hlo=include_hlo))
    entries.extend(_calib_entries(cfg))
    entries.extend(_kernel_spec_entries())
    return entries
