"""Pass orchestration: trace the registry, run the pass families, apply
the baseline, report.

Per-entry passes consume one entry's artifacts; global passes see the
whole run (policy-registry audit, cross-entry HBM ordering, bench-file
schemas). A trace failure is itself a finding (``runner:trace-error``) —
the lint never dies on one broken entry point.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import traceback
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from . import bench_schema, hlo_passes, jaxpr_passes, pallas_passes
from .findings import Baseline, Finding, Severity
from .registry import Artifacts, LintEntry, build_entries

PASS_NAMES = ("jaxpr-dtype", "jaxpr-hostsync", "jaxpr-traced-leaves",
              "policy-retrace",
              "hlo-capacity-buffer", "hlo-collectives", "hlo-hbm",
              "pallas-vmem", "pallas-smem", "pallas-dma", "pallas-mxu",
              "pallas-grid", "bench-schema")

DEFAULT_BASELINE = "lint_baseline.json"


@dataclasses.dataclass
class LintReport:
    findings: List[Finding]
    suppressed: List[Finding]
    entries_run: List[str]

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == Severity.ERROR]

    @property
    def exit_code(self) -> int:
        return 1 if self.errors else 0

    def render(self, verbose: bool = False) -> str:
        lines = []
        shown = self.findings if verbose else \
            [f for f in self.findings if f.severity >= Severity.WARNING]
        for f in sorted(shown, key=lambda f: (-f.severity, f.fingerprint)):
            lines.append(f.render())
        n_info = sum(1 for f in self.findings
                     if f.severity == Severity.INFO)
        lines.append(
            f"repro.lint: {len(self.entries_run)} entries, "
            f"{len(self.errors)} error(s), "
            f"{sum(1 for f in self.findings if f.severity == Severity.WARNING)}"
            f" warning(s), {n_info} info, "
            f"{len(self.suppressed)} suppressed")
        return "\n".join(lines)

    def as_json(self) -> str:
        def enc(f: Finding, suppressed: bool):
            return {"fingerprint": f.fingerprint, "severity": str(f.severity),
                    "entry": f.entry, "message": f.message,
                    "detail": f.detail, "suppressed": suppressed}
        return json.dumps(
            {"entries": self.entries_run,
             "findings": [enc(f, False) for f in self.findings]
             + [enc(f, True) for f in self.suppressed]}, indent=2)


def _match(name: str, globs: Optional[Sequence[str]]) -> bool:
    return globs is None or any(fnmatch.fnmatchcase(name, g)
                                for g in globs)


def _entry_passes(entry: LintEntry, art: Artifacts,
                  baseline: Baseline,
                  pass_globs: Optional[Sequence[str]]) -> List[Finding]:
    out: List[Finding] = []
    meta = entry.meta

    def want(p):
        return _match(p, pass_globs)

    if art.jaxpr is not None:
        if want("jaxpr-dtype"):
            out += jaxpr_passes.check_dtype_promotion(art.jaxpr, entry.name)
        if want("jaxpr-hostsync"):
            out += jaxpr_passes.check_host_sync(art.jaxpr, entry.name)
        if want("jaxpr-traced-leaves") and meta.get("traced_leaves"):
            out += jaxpr_passes.check_traced_leaves(
                art.jaxpr, entry.name, meta["traced_leaves"])
    if art.hlo is not None:
        if want("hlo-capacity-buffer") and meta.get("forbid_shapes"):
            out += hlo_passes.check_forbidden_shapes(
                art.hlo, entry.name, meta["forbid_shapes"])
        if want("hlo-capacity-buffer") and meta.get("require_shapes"):
            out += hlo_passes.check_required_shapes(
                art.hlo, entry.name, meta["require_shapes"])
        if want("hlo-collectives") and meta.get("collective_budget"):
            out += hlo_passes.check_collective_budget(
                art.hlo, entry.name, meta["collective_budget"])
        if want("hlo-hbm") and meta.get("hbm_baseline"):
            out += hlo_passes.check_hbm_bytes(
                art.hlo, entry.name, baseline.hbm_bytes.get(entry.name))
    for spec in art.kernel_specs:
        if want("pallas-vmem"):
            out += pallas_passes.check_vmem_footprint(
                spec, entry.name,
                meta.get("vmem_budget", pallas_passes.VMEM_BUDGET_BYTES))
        if want("pallas-smem"):
            out += pallas_passes.check_smem_footprint(
                spec, entry.name,
                meta.get("smem_budget", pallas_passes.SMEM_BUDGET_BYTES))
        if want("pallas-dma"):
            out += pallas_passes.check_dma_streaming(spec, entry.name)
        if want("pallas-mxu"):
            out += pallas_passes.check_mxu_alignment(spec, entry.name)
        if want("pallas-grid"):
            out += pallas_passes.check_grid_coverage(spec, entry.name)
    return out


def run_lint(*, entries: Optional[List[LintEntry]] = None,
             entry_globs: Optional[Sequence[str]] = None,
             pass_globs: Optional[Sequence[str]] = None,
             baseline_path=None,
             repo_root=None,
             update_baselines: bool = False) -> LintReport:
    """Run the suite. ``entry_globs``/``pass_globs``: fnmatch filters over
    entry and pass names (None == all). ``update_baselines`` rewrites the
    baseline file's ``hbm_bytes`` section from this run."""
    repo_root = Path(repo_root) if repo_root else Path.cwd()
    baseline_path = Path(baseline_path) if baseline_path \
        else repo_root / DEFAULT_BASELINE
    baseline = Baseline.load(baseline_path)

    if entries is None:
        entries = build_entries()
    entries = [e for e in entries if _match(e.name, entry_globs)]

    findings: List[Finding] = []
    hlo_by_entry: Dict[str, str] = {}
    ran: List[str] = []
    arts: Dict[str, Artifacts] = {}
    for entry in entries:
        try:
            art = entry.trace()
        except Exception:  # noqa: BLE001 — one broken entry != dead lint
            findings.append(Finding(
                "runner", "trace-error", Severity.ERROR, entry.name,
                "tracing the entry point raised",
                traceback.format_exc(limit=5)))
            continue
        ran.append(entry.name)
        arts[entry.name] = art
        if art.hlo is not None:
            hlo_by_entry[entry.name] = art.hlo

    if update_baselines:
        from ..launch import hlo_analysis as ha
        for name, hlo in hlo_by_entry.items():
            if next((e for e in entries if e.name == name),
                    LintEntry(name, {}, lambda: None)
                    ).meta.get("hbm_baseline"):
                baseline.hbm_bytes[name] = ha.analyze_hlo(hlo).hbm_bytes
        baseline.save(baseline_path)
        baseline = Baseline.load(baseline_path)

    for entry in entries:
        if entry.name in arts:
            findings += _entry_passes(entry, arts[entry.name], baseline,
                                      pass_globs)

    # global passes ------------------------------------------------------
    for entry in entries:
        if entry.meta.get("hbm_less_than") and _match("hlo-hbm",
                                                      pass_globs or ["*"]):
            findings += hlo_passes.check_hbm_ordering(
                hlo_by_entry, entry.name, entry.meta["hbm_less_than"])
    if _match("policy-retrace", pass_globs or ["*"]):
        findings += jaxpr_passes.check_policy_retrace()
    if _match("bench-schema", pass_globs or ["*"]):
        findings += bench_schema.check_bench_files(repo_root)

    kept, suppressed = [], []
    for f in findings:
        (suppressed if baseline.suppression_for(f) is not None
         else kept).append(f)
    return LintReport(findings=kept, suppressed=suppressed,
                      entries_run=ran)
