"""``repro.lint`` — static-analysis pass suite with a CI gate.

Three pass families over a registry of traced public entry points:

  * **jaxpr** — dtype-promotion lint (silent f32 -> f64 upcasts, probed
    under ``jax_enable_x64``), host-sync/callback detection, and a
    retrace-hazard audit of every registered ``SparsityPolicy``'s pytree
    static/traced field split;
  * **HLO** — forbidden capacity-buffer shapes on the fused pipeline
    (generalizing PR 6's bench assertion), per-entry collective-op budgets
    for the shard_map S-ETP paths, HBM-bytes regression against a
    checked-in baseline;
  * **Pallas** — static VMEM-footprint, MXU tile-alignment, and
    grid-coverage checks on the ``KernelSpec`` objects the kernel launches
    derive their own geometry from — no TPU, no tracing.

Run ``python -m repro.lint --ci``; suppress known findings in
``lint_baseline.json``. See README "Static analysis".
"""
from .findings import Baseline, Finding, Severity
from .registry import Artifacts, LintEntry, build_entries
from .runner import LintReport, run_lint

__all__ = ["Artifacts", "Baseline", "Finding", "LintEntry", "LintReport",
           "Severity", "build_entries", "run_lint"]
