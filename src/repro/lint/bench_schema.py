"""Schema validation for the checked-in benchmark trajectory files.

``BENCH_dispatch.json`` / ``BENCH_serving_offline.json`` (flat, overwritten
per run) and ``BENCH_moe_pipeline.json`` (append-only ``runs`` trajectory)
are consumed by CI gates and the README tables; a malformed append silently
corrupts them. The bench scripts call these validators before writing, and
the lint runs them over the repo's checked-in copies.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

from .findings import Finding, Severity

# required keys and their types; numeric fields accept int or float
_NUM = (int, float)

HOST = {"backend": str, "devices": int}

DISPATCH_TOP = {"bench": str, "unit": str, "note": str, "host": dict,
                "smoke": bool, "rows": list}
DISPATCH_ROW = {"T": int, "E": int, "K": int, "d": int, "capacity": int,
                "major_frac": _NUM, "drop_frac": _NUM, "cumsum_us": _NUM,
                "sort_us": _NUM, "speedup": _NUM,
                "tile_skip_fraction": _NUM}

PIPELINE_TOP = {"bench": str, "unit": str, "note": str, "runs": list}
PIPELINE_RUN = {"timestamp": str, "host": dict, "smoke": bool,
                "rows": list}
PIPELINE_ROW = {"T": int, "E": int, "d": int, "f": int, "K": int, "P": int,
                "capacity": int, "buffer_us": _NUM, "fused_us": _NUM,
                "buffer_hbm_bytes": _NUM, "fused_hbm_bytes": _NUM,
                "buffer_capacity_buffers": int, "fused_capacity_buffers": int,
                "rel_err_vs_oracle": _NUM, "overflow_pairs": int}
# added by the streamed-kernel PR; optional so pre-existing trajectory runs
# stay valid. fused_us is the STREAMED kernel from that PR on; resident_us
# is the whole-array-resident variant it replaced.
PIPELINE_ROW_OPTIONAL = {"resident_us": _NUM, "streamed": bool}


SERVING_TOP = {"bench": str, "unit": str, "note": str, "host": dict,
               "smoke": bool, "engines": list, "prefix_sweep": list}
SERVING_ENGINE_ROW = {"engine": str, "requests": int, "tokens": int,
                      "throughput_tok_s": _NUM, "wall_s": _NUM,
                      "compile_s": _NUM, "steady_step_s": _NUM}
SERVING_SWEEP_ROW = {"shared_prefix_frac": _NUM, "hit_rate": _NUM,
                     "throughput_tok_s": _NUM, "chunk_steps": int,
                     "prefill_tokens": int}

OBS_TOP = {"bench": str, "unit": str, "note": str, "runs": list}
OBS_RUN = {"timestamp": str, "host": dict, "smoke": bool, "rows": list}
OBS_ROW = {"engine": str, "decode_steps": int,
           "decode_us_on": _NUM, "decode_us_off": _NUM,
           "tok_s_on": _NUM, "tok_s_off": _NUM, "overhead_frac": _NUM}


def _check_keys(obj: Dict, schema: Dict, where: str,
                optional: Dict = None) -> List[str]:
    errs = []
    if not isinstance(obj, dict):
        return [f"{where}: expected an object, got {type(obj).__name__}"]
    items = list(schema.items()) + [
        (k, t) for k, t in (optional or {}).items() if k in obj]
    for key, typ in items:
        if key not in obj:
            errs.append(f"{where}: missing key {key!r}")
        elif typ is int and isinstance(obj[key], bool):
            errs.append(f"{where}: {key!r} is a bool, expected int")
        elif not isinstance(obj[key], typ):
            want = typ[0].__name__ if isinstance(typ, tuple) \
                else typ.__name__
            errs.append(f"{where}: {key!r} is "
                        f"{type(obj[key]).__name__}, expected {want}")
    return errs


def validate_dispatch_bench(doc: Dict) -> List[str]:
    """Errors in a BENCH_dispatch.json document (empty list == valid)."""
    errs = _check_keys(doc, DISPATCH_TOP, "top-level")
    if isinstance(doc.get("host"), dict):
        errs += _check_keys(doc["host"], HOST, "host")
    for i, row in enumerate(doc.get("rows", []) or []):
        errs += _check_keys(row, DISPATCH_ROW, f"rows[{i}]")
    return errs


def validate_pipeline_bench(doc: Dict) -> List[str]:
    """Errors in a BENCH_moe_pipeline.json document (append-only runs)."""
    errs = _check_keys(doc, PIPELINE_TOP, "top-level")
    for i, run in enumerate(doc.get("runs", []) or []):
        errs += _check_keys(run, PIPELINE_RUN, f"runs[{i}]")
        if not isinstance(run, dict):
            continue
        if isinstance(run.get("host"), dict):
            errs += _check_keys(run["host"], HOST, f"runs[{i}].host")
        for j, row in enumerate(run.get("rows", []) or []):
            errs += _check_keys(row, PIPELINE_ROW, f"runs[{i}].rows[{j}]",
                                optional=PIPELINE_ROW_OPTIONAL)
    return errs


def validate_serving_bench(doc: Dict) -> List[str]:
    """Errors in a BENCH_serving_offline.json document (flat, overwritten).
    ``engines`` must cover both KV layouts; ``prefix_sweep`` rows carry the
    paged engine's hit-rate/throughput curve."""
    errs = _check_keys(doc, SERVING_TOP, "top-level")
    if isinstance(doc.get("host"), dict):
        errs += _check_keys(doc["host"], HOST, "host")
    names = set()
    for i, row in enumerate(doc.get("engines", []) or []):
        errs += _check_keys(row, SERVING_ENGINE_ROW, f"engines[{i}]")
        if isinstance(row, dict):
            names.add(row.get("engine"))
    if doc.get("engines") and not {"contiguous", "paged"} <= names:
        errs.append("engines: must include both 'contiguous' and 'paged' "
                    f"rows (got {sorted(n for n in names if n)})")
    for i, row in enumerate(doc.get("prefix_sweep", []) or []):
        errs += _check_keys(row, SERVING_SWEEP_ROW, f"prefix_sweep[{i}]")
        if isinstance(row, dict) and isinstance(row.get("hit_rate"), _NUM) \
                and not 0.0 <= row["hit_rate"] <= 1.0:
            errs.append(f"prefix_sweep[{i}]: hit_rate "
                        f"{row['hit_rate']} outside [0, 1]")
    return errs


def validate_obs_bench(doc: Dict) -> List[str]:
    """Errors in a BENCH_obs_overhead.json document (append-only runs of
    metrics-on vs metrics-off decode throughput). ``overhead_frac`` is the
    relative decode-time cost of the traced metrics seam and must be a
    sane fraction (the bench itself gates the <= 5%% budget)."""
    errs = _check_keys(doc, OBS_TOP, "top-level")
    for i, run in enumerate(doc.get("runs", []) or []):
        errs += _check_keys(run, OBS_RUN, f"runs[{i}]")
        if not isinstance(run, dict):
            continue
        if isinstance(run.get("host"), dict):
            errs += _check_keys(run["host"], HOST, f"runs[{i}].host")
        for j, row in enumerate(run.get("rows", []) or []):
            errs += _check_keys(row, OBS_ROW, f"runs[{i}].rows[{j}]")
            if isinstance(row, dict) \
                    and isinstance(row.get("overhead_frac"), _NUM) \
                    and not -1.0 <= row["overhead_frac"] <= 10.0:
                errs.append(f"runs[{i}].rows[{j}]: overhead_frac "
                            f"{row['overhead_frac']} is not a credible "
                            "on/off ratio")
    return errs


_VALIDATORS = {
    "BENCH_dispatch.json": validate_dispatch_bench,
    "BENCH_moe_pipeline.json": validate_pipeline_bench,
    "BENCH_serving_offline.json": validate_serving_bench,
    "BENCH_obs_overhead.json": validate_obs_bench,
}


def check_bench_files(repo_root) -> List[Finding]:
    """Lint pass over the repo's checked-in bench files. Absent files are
    fine (fresh clone before any bench run); malformed ones ERROR."""
    out: List[Finding] = []
    root = Path(repo_root)
    for name, validate in _VALIDATORS.items():
        path = root / name
        entry = f"bench/{name}"
        if not path.exists():
            continue
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            out.append(Finding("bench-schema", "invalid-json",
                               Severity.ERROR, entry, f"unparseable: {e}"))
            continue
        for err in validate(doc):
            out.append(Finding(
                "bench-schema", "schema", Severity.ERROR, entry, err,
                "the bench script should have refused this append — fix "
                "the writer, not just the file"))
    return out
