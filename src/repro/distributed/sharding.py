"""Logical-axis sharding rules -> PartitionSpecs.

Every param tree is accompanied by a structurally identical tree of logical
axis-name tuples (see models.layers). This module maps those names onto mesh
axes, dropping any assignment that does not divide the dimension (e.g. MQA's
single KV head on a 16-way model axis -> replicated).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> candidate mesh axes, in priority order.
# "pod" extends the data axis; the model axis hosts TP *and* EP.
RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "window": ("data",),        # sharded KV window for context-parallel decode
    "vocab": ("model",),
    "embed": (),
    "ffn": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "q_per_kv": (),
    "head_dim": (),
    "expert": ("model",),       # EP: experts live on the model axis
    "expert_ffn": (),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "state": (),
    "layers": (),
    None: (),
}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 0


def spec_for(axes: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Mesh, rules=None) -> P:
    """PartitionSpec for one array: per dimension, use the rule's mesh axes
    (possibly a tuple) if their product divides the dim size, else trim."""
    rules = rules or RULES
    out = []
    used: set = set()
    for ax_name, dim in zip(axes, shape):
        cands = rules.get(ax_name, ())
        picked = []
        prod = 1
        for m in cands:
            msz = _axis_size(mesh, m)
            if msz == 0 or m in used:
                continue
            if dim % (prod * msz) == 0:
                picked.append(m)
                prod *= msz
        for m in picked:
            used.add(m)
        out.append(tuple(picked) if len(picked) > 1 else (picked[0] if picked else None))
    return P(*out)


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules=None):
    """NamedSharding tree matching a params tree.

    axes_tree: tree of tuples; shape_tree: matching tree of arrays or
    ShapeDtypeStructs."""
    def one(axes, arr):
        return NamedSharding(mesh, spec_for(axes, arr.shape, mesh, rules))
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def batch_spec(batch_size: int, mesh: Mesh, extra=()) -> P:
    """Shard batch over (pod, data) prefix that divides it."""
    picked = []
    prod = 1
    for m in ("pod", "data"):
        msz = _axis_size(mesh, m)
        if msz and batch_size % (prod * msz) == 0:
            picked.append(m)
            prod *= msz
    lead = tuple(picked) if len(picked) > 1 else (picked[0] if picked else None)
    return P(lead, *extra)


def count_mesh_devices(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
