"""Observability: traced on-device metrics, engine span tracing, export.

Three layers (ISSUE 9 / ROADMAP item 5 sensor substrate):

* ``obs.metrics`` — ``MetricsState``, a pytree of int32 counters and
  per-layer expert-load histograms that rides INSIDE the jitted decode
  cache (zero host syncs, traced leaves so value churn never retraces).
* ``obs.tracing`` — ``SpanTracer``, a host-side wall-clock span recorder
  (submit/prefill_chunk/decode/retire) exportable as Chrome-trace JSON.
* ``obs.export`` — ``MetricsSnapshot`` + Prometheus text exposition,
  structured JSON log lines, and a scrape server for the serve CLI.
"""
from .metrics import MetricsState, ObsCache, metrics_spec
from .tracing import SpanTracer
from .export import (MetricsSnapshot, MetricsServer, parse_prometheus,
                     render_prometheus, snapshot_json_line)

__all__ = [
    "MetricsState", "ObsCache", "metrics_spec",
    "SpanTracer",
    "MetricsSnapshot", "MetricsServer", "render_prometheus",
    "parse_prometheus", "snapshot_json_line",
]
