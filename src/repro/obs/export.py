"""Host-side metrics snapshots and export (Prometheus text / JSON lines).

``MetricsSnapshot`` is a plain host container assembled by
``engine.metrics()`` at step boundaries: counters and gauges keyed by
Prometheus-style series names (``name{label="v",...}``) plus fixed-bucket
``Histogram`` objects for request latency distributions. Rendering
follows the Prometheus text exposition format (version 0.0.4);
``parse_prometheus`` round-trips what ``render_prometheus`` emits so
tests and the serve CLI's self-scrape can validate scrapes end to end.
"""
from __future__ import annotations

import json
import math
import threading
import time
from dataclasses import dataclass, field
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, List, Optional, Sequence, Tuple

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# default buckets for request-latency histograms (seconds)
LATENCY_BUCKETS = (0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                   1.0, 2.5, 5.0, 10.0, 30.0)


class Histogram:
    """Fixed-bucket cumulative histogram (Prometheus semantics)."""

    def __init__(self, buckets: Sequence[float] = LATENCY_BUCKETS):
        self.buckets: Tuple[float, ...] = tuple(float(b) for b in buckets)
        self.counts: List[int] = [0] * (len(self.buckets) + 1)  # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def cumulative(self) -> List[Tuple[float, int]]:
        """[(le, cumulative_count), ...] ending with (+Inf, count)."""
        out, acc = [], 0
        for b, c in zip(self.buckets, self.counts):
            acc += c
            out.append((b, acc))
        out.append((math.inf, self.count))
        return out


@dataclass
class MetricsSnapshot:
    """One point-in-time scrape of an engine's metrics."""
    counters: Dict[str, float] = field(default_factory=dict)
    gauges: Dict[str, float] = field(default_factory=dict)
    histograms: Dict[str, Histogram] = field(default_factory=dict)
    timestamp: float = field(default_factory=time.time)

    # series names are "name" or 'name{label="v",label2="v2"}'
    def counter(self, name: str, value: float, **labels: object) -> None:
        self.counters[_series(name, labels)] = float(value)

    def gauge(self, name: str, value: float, **labels: object) -> None:
        self.gauges[_series(name, labels)] = float(value)

    def histogram(self, name: str,
                  buckets: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(buckets)
        return h

    def merge(self, other: "MetricsSnapshot") -> None:
        self.counters.update(other.counters)
        self.gauges.update(other.gauges)
        self.histograms.update(other.histograms)


def _series(name: str, labels: Dict[str, object]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


def _base_name(series: str) -> str:
    return series.split("{", 1)[0]


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def render_prometheus(snap: MetricsSnapshot) -> str:
    """Prometheus text exposition (0.0.4) of a snapshot."""
    lines: List[str] = []
    seen_type: set = set()

    def type_line(base: str, kind: str) -> None:
        if base not in seen_type:
            seen_type.add(base)
            lines.append(f"# TYPE {base} {kind}")

    for series in sorted(snap.counters):
        type_line(_base_name(series), "counter")
        lines.append(f"{series} {_fmt(snap.counters[series])}")
    for series in sorted(snap.gauges):
        type_line(_base_name(series), "gauge")
        lines.append(f"{series} {_fmt(snap.gauges[series])}")
    for name in sorted(snap.histograms):
        h = snap.histograms[name]
        type_line(name, "histogram")
        for le, c in h.cumulative():
            lines.append(f'{name}_bucket{{le="{_fmt(le)}"}} {c}')
        lines.append(f"{name}_sum {repr(float(h.sum))}")
        lines.append(f"{name}_count {h.count}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> MetricsSnapshot:
    """Parse text produced by :func:`render_prometheus` back into a
    snapshot (histograms are reconstructed bucket-exact)."""
    snap = MetricsSnapshot()
    types: Dict[str, str] = {}
    hist_rows: Dict[str, Dict[str, float]] = {}
    hist_buckets: Dict[str, List[Tuple[float, int]]] = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
            continue
        series, sval = line.rsplit(" ", 1)
        val = math.inf if sval == "+Inf" else float(sval)
        base = _base_name(series)
        # histogram sample lines belong to a declared histogram base name
        hbase = None
        for suffix in ("_bucket", "_sum", "_count"):
            if base.endswith(suffix) and \
                    types.get(base[: -len(suffix)]) == "histogram":
                hbase = base[: -len(suffix)]
                break
        if hbase is not None:
            rows = hist_rows.setdefault(hbase, {})
            if base.endswith("_bucket"):
                le_s = series.split('le="', 1)[1].split('"', 1)[0]
                le = math.inf if le_s == "+Inf" else float(le_s)
                hist_buckets.setdefault(hbase, []).append((le, int(val)))
            elif base.endswith("_sum"):
                rows["sum"] = val
            else:
                rows["count"] = val
        elif types.get(base) == "gauge":
            snap.gauges[series] = val
        else:
            snap.counters[series] = val
    for name, pairs in hist_buckets.items():
        pairs.sort(key=lambda p: p[0])
        finite = [p for p in pairs if p[0] != math.inf]
        h = Histogram([le for le, _ in finite])
        prev = 0
        for i, (_, cum) in enumerate(finite):
            h.counts[i] = cum - prev
            prev = cum
        rows = hist_rows.get(name, {})
        h.count = int(rows.get("count", pairs[-1][1] if pairs else 0))
        h.counts[-1] = h.count - prev
        h.sum = float(rows.get("sum", 0.0))
        snap.histograms[name] = h
    return snap


def snapshot_json_line(snap: MetricsSnapshot, **extra: object) -> str:
    """One structured JSON log line for ``--metrics-log``."""
    doc = {
        "ts": snap.timestamp,
        "counters": dict(snap.counters),
        "gauges": dict(snap.gauges),
        "histograms": {
            name: {"buckets": list(h.buckets), "counts": list(h.counts),
                   "sum": h.sum, "count": h.count}
            for name, h in snap.histograms.items()},
    }
    doc.update(extra)
    return json.dumps(doc, sort_keys=True)


class MetricsServer:
    """Minimal stdlib HTTP scrape endpoint serving ``/metrics``.

    ``source`` is called per scrape and must return a MetricsSnapshot;
    pass ``port=0`` to bind an ephemeral port (see ``.port``).
    """

    def __init__(self, source: Callable[[], MetricsSnapshot],
                 port: int = 0, host: str = "127.0.0.1"):
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (stdlib API)
                if self.path.split("?")[0] not in ("/metrics", "/"):
                    self.send_error(404)
                    return
                try:
                    body = render_prometheus(server.source()).encode()
                except Exception as e:  # surface scrape errors as 500s
                    self.send_error(500, str(e))
                    return
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # silence per-request stderr spam
                pass

        self.source = source
        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = int(self._httpd.server_address[1])
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"
