"""Traced on-device metrics (the sensor half of ``repro.obs``).

``MetricsState`` is a registered pytree of int32 arrays that rides INSIDE
the jitted decode cache, exactly where the old ``cache["moe_overflow"]``
scalar used to sit — but as one uniform seam instead of three divergent
per-engine accumulation paths:

* ``expert_load`` — (n_layers, n_sub) histogram of KEPT token/sub-expert
  pairs per sub-expert per layer (routing-time counts, pre-capacity).
* ``kept_full`` / ``kept_major`` — kept sub-pair counts attributed to the
  2T-Drop mode of their original pair (FULL = any minor half kept;
  MAJOR = major half of a major-only pair). With P == 1 every kept pair
  counts as FULL.
* ``dropped_pairs`` — sub-pairs dropped by the sparsity policy
  (``total - kept``; the paper's drop rate is dropped / total).
* ``overflow_pairs`` — KEPT pairs silently discarded by dispatch-capacity
  overflow (unsanctioned accuracy loss; 0 under ``exact_moe``).

Every field is a plain array leaf: values change every step, shapes never
do, so jit sees traced leaves (guarded by the ``jaxpr-traced-leaves`` lint
pass) and nothing retraces. No callbacks, no host syncs — engines drain
the state into host snapshots only at step boundaries via
``engine.metrics()``.

``ObsCache`` is the decode-cache dict type: a registered dict subclass
whose legacy ``cache["moe_overflow"]`` key is kept as a deprecated
read-through to ``metrics.overflow_pairs``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# the stats-dict keys produced per MoE layer by the forward/decode paths;
# field order of MetricsState and stacking in from_stacked rely on these
STAT_KEYS = ("expert_load", "kept_full", "kept_major", "dropped_pairs",
             "overflow_pairs")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class MetricsState:
    """Device-resident MoE metrics accumulator (all int32 leaves)."""
    expert_load: jax.Array       # (n_layers, n_sub)
    kept_full: jax.Array         # ()
    kept_major: jax.Array        # ()
    dropped_pairs: jax.Array     # ()
    overflow_pairs: jax.Array    # ()

    def tree_flatten(self):
        return ((self.expert_load, self.kept_full, self.kept_major,
                 self.dropped_pairs, self.overflow_pairs), None)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        del aux
        return cls(*leaves)

    # -- constructors ----------------------------------------------------

    @classmethod
    def zeros(cls, n_layers: int, n_sub: int) -> "MetricsState":
        # distinct buffers per field: engines donate the cache these live
        # in, and XLA rejects the same buffer donated twice
        z = jnp.zeros((4,), jnp.int32)
        return cls(expert_load=jnp.zeros((n_layers, n_sub), jnp.int32),
                   kept_full=z[0], kept_major=z[1], dropped_pairs=z[2],
                   overflow_pairs=z[3])

    @classmethod
    def from_stacked(cls, stats: Dict[str, jax.Array]) -> "MetricsState":
        """From per-layer stats stacked by ``jax.lax.scan``: expert_load is
        already (n_layers, n_sub); scalar counters come in as (n_layers,)
        and sum over layers."""
        return cls(
            expert_load=stats["expert_load"].astype(jnp.int32),
            kept_full=jnp.sum(stats["kept_full"]).astype(jnp.int32),
            kept_major=jnp.sum(stats["kept_major"]).astype(jnp.int32),
            dropped_pairs=jnp.sum(stats["dropped_pairs"]).astype(jnp.int32),
            overflow_pairs=jnp.sum(stats["overflow_pairs"]).astype(jnp.int32))

    # -- accumulation (in-jit) -------------------------------------------

    def __add__(self, other: "MetricsState") -> "MetricsState":
        return MetricsState(
            expert_load=self.expert_load + other.expert_load,
            kept_full=self.kept_full + other.kept_full,
            kept_major=self.kept_major + other.kept_major,
            dropped_pairs=self.dropped_pairs + other.dropped_pairs,
            overflow_pairs=self.overflow_pairs + other.overflow_pairs)

    def accumulate(self, stats: Dict[str, jax.Array]) -> "MetricsState":
        """Fold one step's scan-stacked per-layer stats into the total."""
        return self + MetricsState.from_stacked(stats)

    # -- host snapshot (the ONLY sync point) -----------------------------

    def snapshot(self) -> Dict[str, np.ndarray]:
        """Pull values to host (one transfer per leaf; call at step
        boundaries, never inside the serving loop's hot path)."""
        return {k: np.asarray(getattr(self, k)) for k in STAT_KEYS}

    @property
    def total_pairs(self):
        return self.kept_full + self.kept_major + self.dropped_pairs


def metrics_spec(cfg, params) -> Optional[Tuple[int, int]]:
    """(n_layers, n_sub_experts) for a layer-stacked MoE param tree
    (``params["blocks"]["moe"]["w1"]`` shaped (n_layers, n_sub, d, f) —
    works on prepared/partitioned params AND abstract ShapeDtypeStructs),
    or None when the model has no scannable MoE stack."""
    if not getattr(cfg, "is_moe", False):
        return None
    try:
        w1 = params["blocks"]["moe"]["w1"]
    except (KeyError, TypeError, IndexError):
        return None
    return int(w1.shape[0]), int(w1.shape[1])


class ObsCache(dict):
    """Decode-cache dict. Identical to dict except that the retired
    ``"moe_overflow"`` key reads through to ``metrics.overflow_pairs``
    with a DeprecationWarning (``cache["metrics"]`` is the seam now)."""

    def __getitem__(self, key):
        if key == "moe_overflow" and not dict.__contains__(self, key) \
                and dict.__contains__(self, "metrics"):
            warnings.warn(
                'cache["moe_overflow"] is deprecated; read '
                'cache["metrics"].overflow_pairs (obs.MetricsState) instead',
                DeprecationWarning, stacklevel=2)
            return dict.__getitem__(self, "metrics").overflow_pairs
        return dict.__getitem__(self, key)


def _obs_cache_flatten(c: ObsCache):
    keys = tuple(sorted(c))
    return tuple(dict.__getitem__(c, k) for k in keys), keys


def _obs_cache_unflatten(keys, values) -> ObsCache:
    out = ObsCache()
    for k, v in zip(keys, values):
        dict.__setitem__(out, k, v)
    return out


jax.tree_util.register_pytree_node(ObsCache, _obs_cache_flatten,
                                   _obs_cache_unflatten)
