"""Host-side engine span tracing.

``SpanTracer`` records wall-clock spans (submit/prefill_chunk/decode/
retire and friends) as the engines run: a bounded in-memory event buffer
with ``time.perf_counter`` timestamps, exportable as Chrome-trace
(Perfetto / chrome://tracing) JSON. It is pure host bookkeeping — it
never touches device arrays, so it adds no syncs to the jitted hot path.

Spans nest naturally: an ``engine.step`` span opened by ``EngineBase``
contains the ``decode`` / ``prefill_chunk`` spans the engine opens
inside it, and the viewer reconstructs the hierarchy from timestamps.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional


class SpanTracer:
    """Bounded recorder of wall-clock spans and instant events.

    Disabled tracers ( ``enabled=False`` ) keep every call a cheap no-op
    so engines can invoke hooks unconditionally.
    """

    def __init__(self, *, enabled: bool = True, max_events: int = 100_000):
        self.enabled = enabled
        self.max_events = int(max_events)
        self._events: List[Dict[str, Any]] = []
        self._origin = time.perf_counter()
        self._lock = threading.Lock()
        self._dropped = 0

    # -- recording -------------------------------------------------------

    def _push(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self._dropped += 1
                return
            self._events.append(ev)

    @contextmanager
    def span(self, name: str, **args: Any):
        """Record a complete-duration ("X") event around the body."""
        if not self.enabled:
            yield
            return
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            self._push({"name": name, "ph": "X",
                        "ts": (t0 - self._origin) * 1e6,
                        "dur": (t1 - t0) * 1e6, "args": args})

    def instant(self, name: str, **args: Any) -> None:
        """Record a zero-duration ("i") marker event."""
        if not self.enabled:
            return
        self._push({"name": name, "ph": "i",
                    "ts": (time.perf_counter() - self._origin) * 1e6,
                    "s": "t", "args": args})

    # -- queries ---------------------------------------------------------

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def durations(self, name: str) -> List[float]:
        """Seconds spent in every completed span with this name."""
        return [ev["dur"] / 1e6 for ev in self.events()
                if ev["ph"] == "X" and ev["name"] == name]

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0
            self._origin = time.perf_counter()

    # -- export ----------------------------------------------------------

    def chrome_trace(self, *, pid: int = 1, tid: int = 1) -> Dict[str, Any]:
        """Chrome-trace JSON object (``traceEvents`` array format)."""
        out = []
        for ev in self.events():
            ce = dict(ev)
            ce.setdefault("pid", pid)
            ce.setdefault("tid", tid)
            ce.setdefault("cat", "engine")
            out.append(ce)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self._dropped}}

    def write_chrome_trace(self, path: str, **kw: Any) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(**kw), f)


_NULL: Optional[SpanTracer] = None


def null_tracer() -> SpanTracer:
    """Shared disabled tracer (every method is a no-op)."""
    global _NULL
    if _NULL is None:
        _NULL = SpanTracer(enabled=False, max_events=0)
    return _NULL
