"""Serving with the DualSparse-MoE inference system (paper §4-§5.3):
throughput run (scaled down for CPU) comparing baseline vs 2T-Drop serving,
on both the synchronized-batch engine and the continuous-batching engine
(mixed-length requests admitted into slots as they free up).

    PYTHONPATH=src python examples/serve_dualsparse.py --requests 8
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM, calibration_activations
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.transformer import DistContext
from repro.serving import (ContinuousBatchingEngine, GenerationConfig,
                           ServingEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-lite")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=50)
    ap.add_argument("--new-tokens", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    src = SyntheticLM(cfg.vocab_size)
    prompts = [np.asarray(src.sample_batch(jax.random.fold_in(key, i), 1,
                                           args.prompt_len)["tokens"][0])
               for i in range(args.requests)]
    gen = GenerationConfig(max_new_tokens=args.new_tokens)

    def throughput(engine):
        t0 = time.time()
        res = engine.generate(prompts, gen)
        dt = time.time() - t0
        return sum(len(r.tokens) for r in res) / dt, res

    base_eng = ServingEngine(cfg, params, batch_size=args.requests,
                             max_prompt_len=args.prompt_len,
                             max_new_tokens=args.new_tokens)
    base_tps, base_res = throughput(base_eng)
    print(f"baseline (sync)  : {base_tps:.1f} tok/s")

    calib = calibration_activations(jax.random.fold_in(key, 7), 512,
                                    cfg.d_model)
    from repro.core.policy import make_policy
    policy = make_policy("2t", cfg.dualsparse)
    tparams, policy = policy.prepare(params, cfg, calib)
    dist = DistContext(mesh=make_host_mesh(1), moe_impl="dispatch",
                       policy=policy)
    ds_eng = ServingEngine(cfg, tparams, batch_size=args.requests,
                           max_prompt_len=args.prompt_len,
                           max_new_tokens=args.new_tokens, dist=dist)
    ds_tps, ds_res = throughput(ds_eng)
    print(f"DualSparse 2T    : {ds_tps:.1f} tok/s "
          f"(T²=({policy.t_major}, {policy.t_minor}))")

    agree = np.mean([a.tokens == b.tokens
                     for a, b in zip(base_res, ds_res)])
    print(f"greedy outputs identical on {agree:.0%} of requests "
          "(drop perturbs low-score experts only)")

    # continuous batching: same DualSparse DistContext threads through the
    # per-slot decode path unchanged; requests flow through a small slot pool
    cont_eng = ContinuousBatchingEngine(
        cfg, tparams, n_slots=args.slots, max_prompt_len=args.prompt_len,
        max_new_tokens=args.new_tokens, dist=dist)
    cont_tps, cont_res = throughput(cont_eng)
    print(f"DualSparse 2T + continuous batching ({args.slots} slots): "
          f"{cont_tps:.1f} tok/s — admitted {cont_eng.n_admitted} requests "
          f"over {cont_eng.decode_steps} decode steps, "
          f"{cont_eng.decode_traces} decode trace(s)")


if __name__ == "__main__":
    main()
