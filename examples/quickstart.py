"""Quickstart: the DualSparse-MoE pipeline end to end on a tiny MoE model.

    PYTHONPATH=src python examples/quickstart.py

1. Build an OLMoE-layout MoE model (random "pre-trained" weights).
2. Profile neuron importance on calibration data (paper Eq. 15).
3. Reconstruct experts into major/minor halves + partial transformation.
4. Compare full vs 1T-Drop vs 2T-Drop outputs and FLOPs savings.
5. Generate a few tokens with 2T-Drop enabled.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import drop, gating, moe, reconstruct
from repro.data.pipeline import SyntheticLM, calibration_activations
from repro.models import model as M
from repro.serving import GenerationConfig, ServingEngine


def main():
    cfg = get_config("olmoe-lite")
    key = jax.random.PRNGKey(0)
    print(f"model: {cfg.arch_id} — {cfg.n_experts} experts, top-{cfg.top_k}, "
          f"~{cfg.n_params()/1e6:.1f}M params")
    params = M.init_params(key, cfg)

    # --- 2+3: profile + reconstruct + partial transformation (paper §4.2) ---
    calib = calibration_activations(jax.random.fold_in(key, 1), 512,
                                    cfg.d_model)
    moe_layer0 = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
    imp = reconstruct.neuron_importance(moe_layer0, calib, cfg, "abs_gate")
    print(f"neuron importance: shape {imp.shape}, "
          f"top/bottom ratio {float(imp.max()/imp.min()):.1f}")
    rec = reconstruct.partition_and_reconstruct(moe_layer0, calib, cfg, p=2)
    print(f"partitioned experts: {moe_layer0['w1'].shape} -> "
          f"{rec['w1'].shape} (major/minor sub-experts)")

    # --- 4: drop comparison on one MoE layer ---
    x = calib[:256]
    y_full = moe.moe_forward_ref(moe_layer0, x, cfg)
    r = gating.route(x, moe_layer0["wg"], cfg.top_k, cfg.router_norm_topk)
    t1 = float(jnp.quantile(r.norm_score, 0.25))
    for name, pairs in [
        ("1T-Drop", drop.expand_pairs_1t(r.idx, r.combine, r.norm_score, 2,
                                         t1)),
        ("2T-Drop", drop.expand_pairs_2t(r.idx, r.combine, r.norm_score, 2,
                                         t1 - 0.005, t1 + 0.005)),
    ]:
        y = moe.moe_forward_ref(rec, x, cfg, pairs=pairs)
        fs = float(drop.flops_saved_fraction(pairs.modes))
        err = float(jnp.sqrt(jnp.mean((y - y_full) ** 2) /
                             jnp.mean(y_full ** 2)))
        print(f"{name}: flops saved {fs:.1%}, relative output error {err:.4f}")

    # --- 5: generate with the full DualSparse model. ONE policy object
    # carries partition factor, thresholds, and execution hints end to end.
    from repro.core.policy import make_policy
    from repro.models.transformer import DistContext
    from repro.launch.mesh import make_host_mesh
    policy = make_policy("2t", cfg.dualsparse)
    tparams, policy = policy.prepare(params, cfg, calib)
    dist = DistContext(mesh=make_host_mesh(1), moe_impl="dispatch",
                       policy=policy)
    eng = ServingEngine(cfg, tparams, batch_size=2, max_prompt_len=16,
                        max_new_tokens=12, dist=dist)
    src = SyntheticLM(cfg.vocab_size)
    prompts = [np.asarray(src.sample_batch(jax.random.fold_in(key, i), 1,
                                           16)["tokens"][0])
               for i in range(2)]
    results = eng.generate(prompts, GenerationConfig(max_new_tokens=12))
    for res in results:
        print(f"request {res.uid}: generated {res.tokens}")
    print("OK")


if __name__ == "__main__":
    main()
