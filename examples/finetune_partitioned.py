"""End-to-end training driver (paper §3.1 / Fig 4): fine-tune a ~100M-param
MoE model for a few hundred steps, original granularity vs complete-
transformation-partitioned (P=2), and compare loss curves.

    PYTHONPATH=src python examples/finetune_partitioned.py --steps 300

This is the (b)-deliverable end-to-end driver: real data pipeline, AdamW +
cosine schedule, gradient clipping, checkpointing, loss reporting.
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro import checkpoint as ckpt
from repro.configs.base import ModelConfig, DualSparseConfig
from repro.core import partition
from repro.data import pipeline
from repro.models import model as M
from repro.optim import adamw, cosine_schedule

# ~100M params: 8 layers, d_model 512, 16 experts x d_expert 512 top-2,
# vocab 16k  ->  emb 2x8.2M + 8 x (attn 1.3M + moe 12.6M) ≈ 128M
CFG_100M = ModelConfig(
    arch_id="moe-100m", family="moe", source="examples",
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=2, d_ff=512,
    vocab_size=16384, n_experts=16, top_k=2, d_expert=512,
    dualsparse=DualSparseConfig(enabled=True))


def partition_model(params, p):
    out = dict(params)
    blocks = dict(params["blocks"])
    blocks["moe"] = jax.vmap(
        lambda mp: partition.complete_transform(mp, p))(blocks["moe"])
    out["blocks"] = blocks
    return out


def train(cfg, params, steps, batch, seq, lr, tag, log_every=20,
          ckpt_dir=None):
    opt = adamw(cosine_schedule(lr, steps, warmup=max(steps // 20, 5)))
    ost = opt.init(params)
    step_fn = jax.jit(M.make_train_step(cfg, opt, aux_coef=0.01))
    loader = pipeline.make_loader(cfg, batch, seq)
    t0 = time.time()
    losses = []
    for i in range(steps):
        params, ost, loss = step_fn(params, ost, loader.get_batch(i))
        losses.append(float(loss))
        if (i + 1) % log_every == 0:
            print(f"[{tag}] step {i+1:4d} loss {losses[-1]:.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
        if ckpt_dir and (i + 1) % 100 == 0:
            ckpt.save_checkpoint(ckpt_dir, i + 1, {"params": params})
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"params ~{cfg.n_params()/1e6:.0f}M; {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq}")
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)

    # original granularity: top-2 of 16
    l_orig = train(cfg, params, args.steps, args.batch, args.seq, args.lr,
                   "orig  top2/16e", ckpt_dir=args.ckpt_dir)

    # complete transformation P=2: top-4 of 32 — same function at init
    cfg_p = dataclasses.replace(cfg, n_experts=32, top_k=4, d_expert=256)
    params_p = partition_model(params, 2)
    l_part = train(cfg_p, params_p, args.steps, args.batch, args.seq,
                   args.lr, "P=2   top4/32e")

    n = max(args.steps // 10, 1)
    print("\nfinal-10% mean loss:")
    print(f"  original    : {sum(l_orig[-n:])/n:.4f}")
    print(f"  partitioned : {sum(l_part[-n:])/n:.4f}")
    print("(paper Fig 4: partitioned experts reach lower fine-tuning loss)")


if __name__ == "__main__":
    main()
