"""Benchmark harness — one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only fig9,table2]
Output: ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from .common import print_rows

BENCHES = [
    ("table1", "benchmarks.bench_table1_partition"),
    ("table2", "benchmarks.bench_table2_drop"),
    ("table3", "benchmarks.bench_table3_related"),
    ("fig9", "benchmarks.bench_fig9_setp"),
    ("fig10", "benchmarks.bench_fig10_speedup"),
    ("fig11", "benchmarks.bench_fig11_load_aware"),
    ("fig12", "benchmarks.bench_fig12_thresholds"),
    ("dispatch", "benchmarks.bench_dispatch"),
    ("importance", "benchmarks.bench_importance"),
    ("kernel_skip", "benchmarks.bench_kernel_skip"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench keys to run")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for key, modname in BENCHES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            rows = mod.run()
            print_rows(rows)
            print(f"# {key} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {key} FAILED:", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
