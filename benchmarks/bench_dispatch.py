"""Dispatch substrate benchmark: sort-based vs one-hot-cumsum seating.

Times ONE dispatch step (plan + buffer materialization — the quantity every
MoE layer pays before its expert GEMMs) for both implementations over a
T x E grid, plus the mode-ordered 2T variant with its analytic MXU
tile-skip fraction (what ``counts_major`` buys the dual-sparse kernel).

Emits ``BENCH_dispatch.json`` (repo root by default) so the perf trajectory
of this path is tracked across PRs, and CSV rows for ``benchmarks.run``.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_dispatch [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core import dispatch as D

from .common import Row, time_fn

K = 8
D_MODEL = 64
FULL_SWEEP = [(T, E) for T in (256, 1024, 4096, 16384)
              for E in (8, 64, 256)]
SMOKE_SWEEP = [(256, 8), (1024, 64)]
# mode-ordered cases: fraction of kept pairs that are MAJOR-only / dropped
MODE_CASES = [(0.0, 0.0), (0.3, 0.1)]


def _case(T: int, E: int, major_frac: float, drop_frac: float, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    idx = jax.random.randint(ks[0], (T, K), 0, E)
    x = jax.random.normal(ks[1], (T, D_MODEL))
    keep = ~jax.random.bernoulli(ks[2], drop_frac, (T, K))
    major = jax.random.bernoulli(ks[3], major_frac, (T, K)) & keep
    cap = max(8, int(np.ceil(1.25 * T * K / E / 8)) * 8)
    return idx, x, keep, major, cap


def _dispatch_step(plan_fn, build_fn, E: int, cap: int):
    def step(idx, x, keep, major):
        plan = plan_fn(idx, keep, n_groups=E, capacity=cap, major_only=major)
        return build_fn(x, plan, cap, index_div=K), plan.overflow
    return jax.jit(step)


def run(smoke: bool = False, out_path: str | None = None) -> list[Row]:
    sweep = SMOKE_SWEEP if smoke else FULL_SWEEP
    iters = 3 if smoke else 7
    rows: list[Row] = []
    results = []
    for T, E in sweep:
        for major_frac, drop_frac in (MODE_CASES[:1] if smoke else MODE_CASES):
            idx, x, keep, major, cap = _case(T, E, major_frac, drop_frac)
            t_cum = time_fn(
                _dispatch_step(D.cumsum_dispatch, D.scatter_rows, E, cap),
                idx, x, keep, major, iters=iters, warmup=1)
            t_sort = time_fn(
                _dispatch_step(D.sort_dispatch, D.gather_rows, E, cap),
                idx, x, keep, major, iters=iters, warmup=1)
            plan = D.sort_dispatch(idx, keep, n_groups=E, capacity=cap,
                                   major_only=major)
            skip = _tile_skip(plan, cap) if major_frac > 0 else 0.0
            tag = f"dispatch/T{T}_E{E}_maj{major_frac:.1f}"
            rows.append((f"{tag}/cumsum", t_cum, ""))
            rows.append((f"{tag}/sort", t_sort,
                         f"speedup={t_cum / t_sort:.2f}x "
                         f"tile_skip={skip:.3f}"))
            results.append({
                "T": T, "E": E, "K": K, "d": D_MODEL, "capacity": cap,
                "major_frac": major_frac, "drop_frac": drop_frac,
                "cumsum_us": t_cum, "sort_us": t_sort,
                "speedup": t_cum / t_sort, "tile_skip_fraction": skip,
            })
    payload = {
        "bench": "dispatch",
        "unit": "us_per_dispatch_step",
        "note": "plan + buffer materialization; sort-based vs dense "
                "one-hot cumsum (core.dispatch)",
        "host": {"backend": jax.default_backend(),
                 "devices": jax.device_count()},
        "smoke": smoke,
        "rows": results,
    }
    out_path = out_path or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_dispatch.json")
    from repro.lint.bench_schema import validate_dispatch_bench
    schema_errs = validate_dispatch_bench(payload)
    assert not schema_errs, (
        "refusing to write a malformed BENCH_dispatch.json: "
        + "; ".join(schema_errs))
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return rows


def _tile_skip(plan: D.DispatchPlan, cap: int, f: int = 256,
               block_c: int = 128, block_f: int = 128) -> float:
    """Analytic fraction of (token-block x neuron-block) MXU tiles the
    dual-sparse kernel never issues for these counts (see
    bench_kernel_skip.tile_skip_fraction; f/2 is the minor boundary)."""
    from .bench_kernel_skip import tile_skip_fraction
    cf, cm = (np.asarray(a) for a in plan.kernel_counts(cap))
    return float(tile_skip_fraction(cf, cm, cap, f,
                                    block_c=min(block_c, cap),
                                    block_f=min(block_f, f)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, not minutes)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()
    t0 = time.time()
    rows = run(smoke=args.smoke, out_path=args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"# dispatch bench done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
