"""Paper Table 1 + Fig 4: expert partition (complete transformation)
preserves accuracy exactly, and partitioned models fine-tune to lower loss.

Without pretrained Mixtral weights, the Table-1 'same downstream accuracy'
claim becomes an output-equivalence check (max |Δ| over tokens), and the
Fig-4 fine-tuning claim is run on a reduced Mixtral-layout model trained on
the synthetic pipeline — original (top-2/8) vs P=2 (top-4/16) vs
P=4 (top-8/32)."""
from __future__ import annotations

import dataclasses

import jax

from repro.configs import get_config
from repro.core import moe, partition
from repro.data import pipeline
from repro.models import model as M
from repro.models.layers import split_params
from repro.optim import adamw

from .common import Row, rel_err, time_fn


def _partitioned_cfg(cfg, p):
    return dataclasses.replace(cfg, n_experts=cfg.n_experts * p,
                               top_k=cfg.top_k * p,
                               d_expert=cfg.d_expert // p)


def _partition_model(params, p):
    out = dict(params)
    blocks = dict(params["blocks"])
    blocks["moe"] = jax.vmap(
        lambda mp: partition.complete_transform(mp, p))(blocks["moe"])
    out["blocks"] = blocks
    return out


def run() -> list[Row]:
    rows: list[Row] = []
    cfg = get_config("mixtral-8x7b-lite")
    key = jax.random.PRNGKey(0)

    # --- Table 1 upper block: transformation exactness on the MoE layer ---
    mp, _ = split_params(moe.make_moe_params(key, cfg))
    x = pipeline.calibration_activations(key, 128, cfg.d_model)
    y0 = moe.moe_forward_ref(mp, x, cfg)
    for p in (2, 4):
        pc = partition.complete_transform(mp, p)
        yc = moe.moe_forward_ref(pc, x, _partitioned_cfg(cfg, p))
        rows.append((f"table1/complete_P{p}_rel_err", 0.0,
                     f"rel_err={rel_err(yc, y0):.2e} (exact; Eq.11)"))

    # --- Fig 4: fine-tuning loss, original vs partitioned ---
    loader = pipeline.make_loader(cfg, 8, 32)
    for p in (1, 2, 4):
        params = M.init_params(key, cfg)
        cfg_p = _partitioned_cfg(cfg, p) if p > 1 else cfg
        params_p = _partition_model(params, p) if p > 1 else params
        opt = adamw(3e-3)
        ost = opt.init(params_p)
        step = jax.jit(M.make_train_step(cfg_p, opt))
        loss = None
        for i in range(30):
            params_p, ost, loss = step(params_p, ost, loader.get_batch(i))
        us = time_fn(step, params_p, ost, loader.get_batch(0), iters=3)
        rows.append((f"fig4/finetune_P{p}_loss30", us,
                     f"loss={float(loss):.4f} top{cfg.top_k*p}/"
                     f"{cfg.n_experts*p}e"))
    return rows
