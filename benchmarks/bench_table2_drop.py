"""Paper Table 2: drop-method comparison on the three evaluation-model
layouts (Mixtral-like, OLMoE-like, DeepSeek-V2-Lite-like).

Accuracy proxy (no pretrained weights): relative RMS output error vs the
no-drop model on calibration inputs, at matched drop rates. The paper's
ordering to reproduce: err(2T reconstruct) < err(2T partition) ≈ err(1T)."""
from __future__ import annotations

import jax

from repro.configs import get_config
from repro.core import drop, moe
from repro.core.policy import OneTDrop, TwoTDrop
from repro.data import pipeline
from repro.models.layers import split_params

from .common import Row, rel_err, sharp_router_params

MODELS = ["mixtral-8x7b-lite", "olmoe-lite", "dsv2-lite-lite"]

# the sweep: each variant is ONE policy (reconstruction on/off is a policy
# knob, so "2T with plain partition" vs "2T with reconstruction" differ only
# in the object handed to prepare). Thresholds calibrate to the paper's
# ~25% operating point inside prepare().
TARGET = 0.25
VARIANTS = [
    ("1T-Drop", OneTDrop(partition_p=2, reconstruction=False,
                         drop_target=TARGET)),
    ("2T-partition", TwoTDrop(partition_p=2, reconstruction=False,
                              drop_target=TARGET)),
    ("2T-reconstruct", TwoTDrop(partition_p=2, reconstruction=True,
                                drop_target=TARGET)),
]


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(1)
    for name in MODELS:
        cfg = get_config(name)
        params, _ = split_params(moe.make_moe_params(key, cfg))
        params = sharp_router_params(params)
        x = pipeline.calibration_activations(jax.random.fold_in(key, 2),
                                             512, cfg.d_model)
        y0 = moe.moe_forward_ref(params, x, cfg)

        for vname, pol in VARIANTS:
            mdl, cal = pol.prepare(params, cfg, x)
            pairs = cal.route(mdl, x, cfg)
            y = moe.moe_forward_ref(mdl, x, cfg, pairs=pairs)
            dr = float(drop.flops_saved_fraction(pairs.modes))
            rows.append((f"table2/{name}/{vname}", 0.0,
                         f"drop_rate={dr:.3f} rel_err={rel_err(y, y0):.4f}"))
    return rows
