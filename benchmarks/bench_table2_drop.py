"""Paper Table 2: drop-method comparison on the three evaluation-model
layouts (Mixtral-like, OLMoE-like, DeepSeek-V2-Lite-like).

Accuracy proxy (no pretrained weights): relative RMS output error vs the
no-drop model on calibration inputs, at matched drop rates. The paper's
ordering to reproduce: err(2T reconstruct) < err(2T partition) ≈ err(1T)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import drop, gating, moe, partition, reconstruct
from repro.data import pipeline
from repro.models.layers import split_params

from .common import Row, rel_err, sharp_router_params

MODELS = ["mixtral-8x7b-lite", "olmoe-lite", "dsv2-lite-lite"]


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(1)
    for name in MODELS:
        cfg = get_config(name)
        params, _ = split_params(moe.make_moe_params(key, cfg))
        params = sharp_router_params(params)
        x = pipeline.calibration_activations(jax.random.fold_in(key, 2),
                                             512, cfg.d_model)
        y0 = moe.moe_forward_ref(params, x, cfg)
        r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
        # threshold at the ~25% drop-rate quantile (paper's operating point)
        t1 = float(jnp.quantile(r.norm_score, 0.25))
        gap = max(min(0.01, t1 * 0.2), 1e-4)

        plain = partition.partial_transform(params, 2)
        rec = reconstruct.partition_and_reconstruct(
            params, x, cfg, p=2, method=cfg.dualsparse.importance)

        p_1t = drop.expand_pairs_1t(r.idx, r.combine, r.norm_score, 2, t1)
        p_2t = drop.expand_pairs_2t(r.idx, r.combine, r.norm_score, 2,
                                    t1 - gap, t1 + gap)
        variants = [
            ("1T-Drop", plain, p_1t),
            ("2T-partition", plain, p_2t),
            ("2T-reconstruct", rec, p_2t),
        ]
        for vname, mdl, pairs in variants:
            y = moe.moe_forward_ref(mdl, x, cfg, pairs=pairs)
            dr = float(drop.flops_saved_fraction(pairs.modes))
            rows.append((f"table2/{name}/{vname}", 0.0,
                         f"drop_rate={dr:.3f} rel_err={rel_err(y, y0):.4f}"))
    return rows
