"""Paper Table 3: comparison with prior work — EES (Efficient Expert
Skipping) and EEP (Efficient Expert Pruning) [Lu et al., 2024], both
implemented here, vs 2T-Drop (partition / reconstruct).

Proxy metrics on the Mixtral-like layout: relative output error (accuracy
proxy), fraction of expert FLOPs removed (speedup proxy), and memory saved
(for pruning)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import drop, gating, moe, partition, reconstruct
from repro.data import pipeline
from repro.models.layers import split_params

from .common import Row, rel_err, sharp_router_params


def ees_keep(r, beta):
    """EES: skip the 2nd expert of top-2 when s2 < beta * s1."""
    keep = jnp.ones_like(r.idx, dtype=bool)
    ratio = r.norm_score[:, 1] / jnp.maximum(r.norm_score[:, 0], 1e-9)
    return keep.at[:, 1].set(ratio >= beta)


def eep_prune(params, usage, r_keep):
    """EEP: permanently keep the r most-used experts; re-route to them."""
    order = jnp.argsort(-usage)
    kept = order[:r_keep]
    mask = jnp.full((usage.shape[0],), -jnp.inf)
    mask = mask.at[kept].set(0.0)
    return kept, mask


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(2)
    cfg = get_config("mixtral-8x7b-lite")
    params, _ = split_params(moe.make_moe_params(key, cfg))
    params = sharp_router_params(params)
    calib = pipeline.calibration_activations(jax.random.fold_in(key, 1),
                                             512, cfg.d_model)
    x = pipeline.calibration_activations(jax.random.fold_in(key, 9),
                                         512, cfg.d_model)
    y0 = moe.moe_forward_ref(params, x, cfg)
    r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)

    # --- 2T-Drop (ours), partition and reconstruct, ~20% drop ---
    t1 = float(jnp.quantile(r.norm_score, 0.2))
    gap = max(min(0.01, t1 * 0.2), 1e-4)
    p2t = drop.expand_pairs_2t(r.idx, r.combine, r.norm_score, 2,
                               t1 - gap, t1 + gap)
    for vname, mdl in [
            ("2T-Drop(partition)", partition.partial_transform(params, 2)),
            ("2T-Drop(reconstruct)", reconstruct.partition_and_reconstruct(
                params, calib, cfg, p=2))]:
        y = moe.moe_forward_ref(mdl, x, cfg, pairs=p2t)
        fs = float(drop.flops_saved_fraction(p2t.modes))
        rows.append((f"table3/{vname}", 0.0,
                     f"flops_saved={fs:.3f} rel_err={rel_err(y, y0):.4f}"
                     " mem_saved=0%"))

    # --- EES baseline: beta = median(s2/s1) on calibration ---
    rc = gating.route(calib, params["wg"], cfg.top_k, cfg.router_norm_topk)
    beta = float(jnp.median(rc.norm_score[:, 1] /
                            jnp.maximum(rc.norm_score[:, 0], 1e-9)))
    keep = ees_keep(r, beta)
    pairs = drop.SubExpertPairs(idx=r.idx, combine=r.combine, keep=keep,
                                modes=jnp.where(keep, drop.MODE_FULL,
                                                drop.MODE_DROP))
    y = moe.moe_forward_ref(params, x, cfg, pairs=pairs)
    fs = float(1 - keep.mean())
    rows.append((f"table3/EES(beta={beta:.2f})", 0.0,
                 f"flops_saved={fs:.3f} rel_err={rel_err(y, y0):.4f}"
                 " mem_saved=0%"))

    # --- EEP baseline: prune to r=6 and r=4 of 8 experts ---
    usage = gating.expert_histogram(rc.idx, cfg.n_experts).astype(jnp.float32)
    for r_keep in (6, 4):
        kept, logit_mask = eep_prune(params, usage, r_keep)
        logits = gating.gate_logits(x, params["wg"]) + logit_mask[None]
        rr = gating.top_k_routing(logits, cfg.top_k, cfg.router_norm_topk)
        pairs = drop.SubExpertPairs(
            idx=rr.idx, combine=rr.combine,
            keep=jnp.ones_like(rr.idx, dtype=bool),
            modes=jnp.full_like(rr.idx, drop.MODE_FULL))
        y = moe.moe_forward_ref(params, x, cfg, pairs=pairs)
        mem = 1 - r_keep / cfg.n_experts
        rows.append((f"table3/EEP(r={r_keep})", 0.0,
                     f"flops_saved=0.000 rel_err={rel_err(y, y0):.4f}"
                     f" mem_saved={mem:.0%}"))
    return rows
