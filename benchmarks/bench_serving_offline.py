"""Offline serving throughput: paged vs contiguous KV, plus a prefix-cache
hit-rate sweep (MLPerf-offline style — every request is available at t=0,
the engine drains the backlog, throughput = generated tokens / wall time).

Two sections:
  * ``engines`` — the same mixed-length workload through the contiguous
    continuous-batching engine and the paged engine (chunked prefill +
    page-table indirection); with exact MoE both emit bit-identical greedy
    tokens, so the delta is pure scheduling/layout cost.
  * ``prefix_sweep`` — workloads whose prompts share a leading prefix of
    varying fraction; the paged engine's prefix cache maps shared pages
    instead of recomputing them. Reports hit rate and prefill work skipped.

Emits ``BENCH_serving_offline.json`` (repo root by default; flat,
overwritten per run) validated against ``repro.lint.bench_schema``.

    PYTHONPATH=src python -m benchmarks.bench_serving_offline [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serving import (ContinuousBatchingEngine, GenerationConfig,
                           PagedEngine)


def make_prompts(cfg, n, lens, *, shared_frac=0.0, seed=0):
    """Mixed-length prompts; ``shared_frac`` of each prompt (from the left)
    is a common prefix across all requests of the same length class."""
    rng = np.random.RandomState(seed)
    shared = rng.randint(0, cfg.vocab_size, max(lens)).astype(np.int32)
    out = []
    for i in range(n):
        L = lens[i % len(lens)]
        p = rng.randint(0, cfg.vocab_size, L).astype(np.int32)
        k = int(L * shared_frac)
        p[:k] = shared[:k]
        out.append(p)
    return out


def drain_timed(eng, prompts, gen):
    """Submit everything up front, drain, return (tok/s, tokens, wall)."""
    for p in prompts:
        eng.submit(p, gen)
    t0 = time.perf_counter()
    res = eng.drain()
    wall = time.perf_counter() - t0
    tokens = sum(len(r.tokens) for r in res)
    return tokens / wall, tokens, wall


def run(smoke: bool = False, out_path: str | None = None) -> dict:
    cfg = get_config("mixtral-8x7b-lite")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    if smoke:
        n_req, lens, new, slots = 6, (8, 16), 4, 2
        page, chunk = 4, 8
        sweep_fracs = (0.0, 1.0)
    else:
        n_req, lens, new, slots = 24, (16, 48, 96), 16, 4
        page, chunk = 16, 32
        sweep_fracs = (0.0, 0.25, 0.5, 0.75, 1.0)
    max_prompt = max(lens)
    gen = GenerationConfig(max_new_tokens=new)
    kw = dict(max_prompt_len=max_prompt, max_new_tokens=new)
    warm = [np.zeros(max_prompt, np.int32)]
    warm_gen = GenerationConfig(max_new_tokens=1)

    # -- engine comparison ------------------------------------------------
    prompts = make_prompts(cfg, n_req, lens)
    engine_rows = []
    for name in ("contiguous", "paged"):
        if name == "contiguous":
            eng = ContinuousBatchingEngine(cfg, params, n_slots=slots, **kw)
        else:
            eng = PagedEngine(cfg, params, n_slots=slots, page_size=page,
                              chunk_size=chunk, **kw)
        eng.generate(warm, warm_gen)       # compile outside the timed drain
        eng.reset_stats()
        tps, tokens, wall = drain_timed(eng, prompts, gen)
        timing = eng.timing
        row = {"engine": name, "requests": n_req, "tokens": tokens,
               "throughput_tok_s": round(tps, 2), "wall_s": round(wall, 4),
               "compile_s": round(timing["compile_s"], 4),
               "steady_step_s": round(timing["steady_step_s"], 6)}
        engine_rows.append(row)
        print(f"{name:11s}: {tps:8.1f} tok/s  ({tokens} tokens, "
              f"{wall:.2f}s wall, compile {row['compile_s']:.2f}s, "
              f"steady step {row['steady_step_s'] * 1e3:.2f}ms)")

    # -- prefix-cache hit-rate sweep -------------------------------------
    sweep_rows = []
    for frac in sweep_fracs:
        eng = PagedEngine(cfg, params, n_slots=slots, page_size=page,
                          chunk_size=chunk, **kw)
        eng.generate(warm, warm_gen)
        eng.reset_stats()
        sp = make_prompts(cfg, n_req, lens, shared_frac=frac, seed=1)
        tps, tokens, wall = drain_timed(eng, sp, gen)
        row = {"shared_prefix_frac": frac,
               "hit_rate": round(eng.prefix_hit_rate, 4),
               "throughput_tok_s": round(tps, 2),
               "chunk_steps": eng.chunk_steps,
               "prefill_tokens": eng.prefill_tokens}
        sweep_rows.append(row)
        print(f"prefix {frac:4.2f}: hit_rate {row['hit_rate']:.2f}  "
              f"{tps:8.1f} tok/s  chunks {eng.chunk_steps}  "
              f"prefilled {eng.prefill_tokens}")

    payload = {
        "bench": "serving_offline",
        "unit": "tok/s",
        "note": "offline (backlog-drain) serving throughput, paged vs "
                "contiguous KV, and the paged engine's prefix-cache sweep "
                "(hit rate + prefill work vs shared-prefix fraction); "
                "greedy tokens are bit-identical across engines under "
                "exact MoE",
        "host": {"backend": jax.default_backend(),
                 "devices": jax.device_count()},
        "smoke": smoke,
        "engines": engine_rows,
        "prefix_sweep": sweep_rows,
    }
    out = out_path or os.path.join(os.path.dirname(__file__), "..",
                                   "BENCH_serving_offline.json")
    from repro.lint.bench_schema import validate_serving_bench
    schema_errs = validate_serving_bench(payload)
    assert not schema_errs, (
        "refusing to write a malformed BENCH_serving_offline.json: "
        + "; ".join(schema_errs))
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {os.path.abspath(out)}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI end-to-end check)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)


if __name__ == "__main__":
    main()
