"""Observability overhead gate: metrics-on vs metrics-off decode throughput.

The traced on-device metrics seam (``repro.obs.MetricsState`` riding in the
decode cache) is designed to be almost free — a handful of int32 adds and
one small histogram per MoE layer, no host syncs, no retraces. This bench
measures exactly that claim on the continuous-batching engine's steady-state
decode step and GATES it: the non-smoke run asserts the relative decode-time
overhead stays within ``MAX_OVERHEAD_FRAC`` (5%).

Method: build two engines over the same params — one with ``metrics=True``,
one with ``metrics=False`` — warm both (compile excluded), then time N
steady decode steps each under ``jax.block_until_ready``. Greedy tokens are
asserted bit-identical between the two runs first, so the timing compares
the same computation ± the metrics seam.

Emits/APPENDS to ``BENCH_obs_overhead.json`` (repo root by default): the
file holds a ``runs`` list — one entry per invocation — validated against
``repro.lint.bench_schema.validate_obs_bench``.

    PYTHONPATH=src python -m benchmarks.bench_obs_overhead [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.lint.bench_schema import validate_obs_bench
from repro.models import model as M
from repro.serving import ContinuousBatchingEngine, GenerationConfig, Request

MAX_OVERHEAD_FRAC = 0.05


def _make_engine(cfg, params, *, metrics, n_slots, max_prompt, max_new):
    return ContinuousBatchingEngine(
        cfg, params, n_slots=n_slots, max_prompt_len=max_prompt,
        max_new_tokens=max_new, cache_dtype=jnp.float32, metrics=metrics)


def _fill_slots(eng, cfg, n_slots, max_prompt, budget, seed=0):
    """Admit one long-budget request per slot so the timed loop below is
    pure steady-state decode at full occupancy."""
    rng = np.random.RandomState(seed)
    for i in range(n_slots):
        prompt = rng.randint(0, cfg.vocab_size, max_prompt - 1).astype(
            np.int32)
        eng.submit(Request(prompt=prompt,
                           gen=GenerationConfig(max_new_tokens=budget)))
    eng.step()                        # admits everything + 1 decode step
    assert eng.free_slots == 0


def _time_decode(eng, n_steps):
    """Mean wall time of one batched decode step over n_steps steps."""
    jax.block_until_ready(eng._cache)
    t0 = time.perf_counter()
    for _ in range(n_steps):
        eng.step()
    jax.block_until_ready(eng._cache)
    return (time.perf_counter() - t0) / n_steps


def _identical_tokens(cfg, params, *, n_slots, max_prompt, max_new):
    """Greedy tokens of a small workload must not depend on the metrics
    seam — otherwise the timing below compares different computations."""
    outs = []
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, max_prompt // 2).astype(
        np.int32) for _ in range(n_slots + 1)]
    for m in (True, False):
        eng = _make_engine(cfg, params, metrics=m, n_slots=n_slots,
                           max_prompt=max_prompt, max_new=max_new)
        res = eng.generate(prompts, GenerationConfig(max_new_tokens=4))
        outs.append([r.tokens for r in res])
    assert outs[0] == outs[1], "metrics seam changed greedy tokens"


def run(smoke: bool = False, out_path: str | None = None) -> dict:
    cfg = get_config("mixtral-8x7b-lite")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    if smoke:
        n_slots, max_prompt, steps, repeats = 2, 16, 8, 1
    else:
        n_slots, max_prompt, steps, repeats = 4, 32, 48, 3
    max_new = steps * (repeats + 2)

    _identical_tokens(cfg, params, n_slots=n_slots, max_prompt=max_prompt,
                      max_new=8)

    per_mode = {}
    decode_steps = 0
    for m in (True, False):
        eng = _make_engine(cfg, params, metrics=m, n_slots=n_slots,
                           max_prompt=max_prompt, max_new=max_new)
        _fill_slots(eng, cfg, n_slots, max_prompt, budget=max_new)
        _time_decode(eng, 2)          # warm: everything traced by now
        assert eng.decode_traces == 1, "steady loop retraced"
        # best-of-repeats: scheduler noise is one-sided
        best = min(_time_decode(eng, steps) for _ in range(repeats))
        per_mode[m] = best
        decode_steps += eng.decode_steps
    t_on, t_off = per_mode[True], per_mode[False]
    overhead = (t_on - t_off) / t_off
    tok_s_on = n_slots / t_on
    tok_s_off = n_slots / t_off
    row = {
        "engine": "continuous", "decode_steps": decode_steps,
        "decode_us_on": round(t_on * 1e6, 2),
        "decode_us_off": round(t_off * 1e6, 2),
        "tok_s_on": round(tok_s_on, 2), "tok_s_off": round(tok_s_off, 2),
        "overhead_frac": round(overhead, 4),
    }
    print(f"decode step: metrics-on {row['decode_us_on']:.0f}us "
          f"({tok_s_on:.1f} tok/s)  metrics-off {row['decode_us_off']:.0f}us "
          f"({tok_s_off:.1f} tok/s)  overhead {overhead * 100:+.2f}%")
    if not smoke:
        assert overhead <= MAX_OVERHEAD_FRAC, (
            f"metrics seam costs {overhead:.1%} of a decode step "
            f"(budget {MAX_OVERHEAD_FRAC:.0%})")

    run_entry = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "host": {"backend": jax.default_backend(),
                 "devices": jax.device_count()},
        "smoke": smoke,
        "rows": [row],
    }
    out = out_path or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_obs_overhead.json")
    payload = {
        "bench": "obs_overhead",
        "unit": "us_per_decode_step",
        "note": "steady-state decode step time of the continuous-batching "
                "engine with the traced on-device metrics seam "
                "(cache['metrics']) enabled vs disabled; greedy tokens are "
                "asserted bit-identical first; non-smoke runs gate "
                "overhead_frac <= 0.05",
        "runs": [],
    }
    if os.path.exists(out):
        try:
            with open(out) as f:
                old = json.load(f)
            if isinstance(old.get("runs"), list):
                payload["runs"] = old["runs"]
        except (json.JSONDecodeError, OSError):
            pass
    payload["runs"].append(run_entry)
    schema_errs = validate_obs_bench(payload)
    assert not schema_errs, (
        "refusing to write a malformed BENCH_obs_overhead.json: "
        + "; ".join(schema_errs))
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {os.path.abspath(out)}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload, no overhead gate (CI check)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)


if __name__ == "__main__":
    main()
