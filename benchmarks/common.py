"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (jit-compiled fn)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def rms(x) -> float:
    return float(jnp.sqrt(jnp.mean(jnp.square(x))))


def rel_err(y, y_ref) -> float:
    return rms(y - y_ref) / max(rms(y_ref), 1e-12)


def print_rows(rows: List[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def moe_overflow(engine_or_cache) -> int:
    """Token-expert pairs silently dropped by dispatch-capacity overflow —
    works on a serving engine (``overflow_pairs``) or a raw decode cache
    (the ``moe_overflow`` running counter). Benchmarks should report this
    next to throughput: an overflow drop is unsanctioned accuracy loss, so
    a speedup bought with overflow>0 is not a clean win."""
    if hasattr(engine_or_cache, "overflow_pairs"):
        return int(engine_or_cache.overflow_pairs)
    if isinstance(engine_or_cache, dict):
        # NB: dict.get bypasses ObsCache's deprecation read-through, so
        # check the metrics seam explicitly before the legacy key
        m = engine_or_cache.get("metrics")
        if m is not None:
            return int(m.overflow_pairs)
        return int(engine_or_cache.get("moe_overflow", 0))
    return 0


def sharp_router_params(params, scale: float = 20.0):
    """Sharpen a random-init router so normalized gating scores spread like a
    trained model's (random init is near-uniform; the paper's drop thresholds
    are meaningless without score spread)."""
    out = dict(params)
    out["wg"] = params["wg"] * scale
    return out
