"""Paper Fig 12: drop rate as a function of threshold, per layer — the
threshold->drop-rate map is nonlinear and layer-dependent, motivating the
tailored mapping used by load-aware thresholding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import drop, gating
from repro.models import model as M

from .common import Row


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(5)
    cfg = get_config("olmoe-lite")
    params = M.init_params(key, cfg)
    # per-layer activations: run the real forward and capture MoE inputs by
    # re-embedding through the blocks (cheap for the lite model)
    batch = M.make_batch(key, cfg, 8, 64, "prefill")
    from repro.models import layers as L
    x = L.embed(params["embed"]["embedding"] if False else params["embed"],
                batch["tokens"])
    thresholds = [0.02, 0.05, 0.08, 0.12, 0.2]
    from repro.models import transformer as T
    pos = jnp.broadcast_to(jnp.arange(64)[None], (8, 64))
    h = x
    for layer in range(cfg.n_layers):
        bp = jax.tree.map(lambda a: a[layer], params["blocks"])
        wg = bp["moe"]["wg"] * 20.0           # sharpened (see common)
        ht = h.reshape(-1, cfg.d_model)
        r = gating.route(ht, wg, cfg.top_k, cfg.router_norm_topk)
        rates = drop.threshold_to_drop_rate(r.norm_score,
                                            jnp.asarray(thresholds))
        rows.append((f"fig12/layer{layer}", 0.0,
                     " ".join(f"T{t}:{float(dr):.3f}"
                              for t, dr in zip(thresholds, rates))))
        h = T.block_forward(bp, h, pos, cfg)

    # beyond-paper (§5.3.3 future work): the per_layer policy calibrates
    # per-layer thresholds that equalize the drop rate across layers
    from repro.core.policy import PerLayerCalibrated2T
    from repro.data.pipeline import calibration_activations
    calib = calibration_activations(jax.random.PRNGKey(9), 512, cfg.d_model)
    pol = PerLayerCalibrated2T(partition_p=2, drop_target=0.25)
    tparams, pol = pol.prepare(params, cfg, calib)
    achieved = []
    for layer in range(cfg.n_layers):
        moe_p = jax.tree.map(lambda a: a[layer], tparams["blocks"]["moe"])
        pairs = pol.route(moe_p, calib, cfg)
        achieved.append(float(drop.flops_saved_fraction(pairs.modes)))
    rows.append(("fig12/per-layer-calibrated@0.25", 0.0,
                 "achieved=" + " ".join(f"{a:.3f}" for a in achieved)))
    return rows
