"""§Roofline: the per-(arch x shape) three-term roofline table, read from
the dry-run sweep (results/dryrun.jsonl, single-pod mesh)."""
from __future__ import annotations

import json
import os

from repro.configs import INPUT_SHAPES, get_config

from .common import Row

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun.jsonl")


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); D = tokens.
    Decode steps process global_batch tokens; train includes backward (3x
    forward's 2ND)."""
    n = cfg.n_params()
    if cfg.is_moe:
        dense_part = n - cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * \
            cfg.d_expert
        active = dense_part + cfg.n_layers * (cfg.top_k + cfg.n_shared_experts) \
            * 3 * cfg.d_model * cfg.d_expert
    else:
        active = n
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch      # decode: one token/seq


def load_records():
    recs = [json.loads(l) for l in open(RESULTS)]
    return [r for r in recs if r.get("mesh") == "16x16"]


def run() -> list[Row]:
    rows: list[Row] = []
    if not os.path.exists(RESULTS):
        return [("roofline/missing", 0.0,
                 "run: python -m repro.launch.dryrun --all --out "
                 "results/dryrun.jsonl")]
    for r in load_records():
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] != "ok":
            rows.append((name, 0.0, r["status"]))
            continue
        rt = r["roofline"]
        terms = {"compute": rt["t_compute"], "memory": rt["t_memory"],
                 "collective": rt["t_collective"]}
        bottleneck = max(terms, key=terms.get)
        cfg = get_config(r["arch"])
        shape = INPUT_SHAPES[r["shape"]]
        mf = model_flops(cfg, shape)
        hlo_global = r["flops"] * r["n_chips"]
        ratio = mf / hlo_global if hlo_global else float("nan")
        rows.append((name, terms[bottleneck] * 1e6,
                     f"t_comp={rt['t_compute']:.2e}s "
                     f"t_mem={rt['t_memory']:.2e}s "
                     f"t_coll={rt['t_collective']:.2e}s "
                     f"bottleneck={bottleneck} "
                     f"model/hlo_flops={ratio:.2f}"))
    return rows
