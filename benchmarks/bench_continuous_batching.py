"""Continuous vs synchronized batching under heterogeneous Poisson traffic.

Requests arrive as a Poisson process with mixed prompt and output lengths —
the regime where synchronized batching loses throughput to convoy effects
(every request in a batch waits for the longest one) and continuous batching
keeps slots busy via mid-decode admission.

Reports, per engine: token throughput, mean/p95 request latency, and the
slot-utilization statistics of the continuous scheduler.

    PYTHONPATH=src python benchmarks/bench_continuous_batching.py \
        --requests 12 --slots 4 --rate 2.0
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.serving import (ContinuousBatchingEngine, GenerationConfig,
                           PagedEngine, ServingEngine)


def make_traffic(cfg, n_requests, rate_hz, prompt_lens, out_lens, seed=0):
    """Poisson arrivals with prompt/output lengths cycled from the mixes."""
    rng = np.random.RandomState(seed)
    src = SyntheticLM(cfg.vocab_size, seed=seed)
    key = jax.random.PRNGKey(seed)
    t = 0.0
    traffic = []
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate_hz)
        pl = prompt_lens[i % len(prompt_lens)]
        ol = out_lens[i % len(out_lens)]
        prompt = np.asarray(src.sample_batch(
            jax.random.fold_in(key, i), 1, pl)["tokens"][0])
        traffic.append((t, prompt, GenerationConfig(max_new_tokens=ol)))
    return traffic


def lat_stats(lats):
    lats = np.asarray(lats)
    return float(lats.mean()), float(np.percentile(lats, 95))


def timing_line(eng):
    """compile-vs-steady split from the engine's step classifier — steps
    that (re)traced a jit are compile, the rest are steady state; a tok/s
    headline that mixes the two misstates both."""
    t = eng.timing
    return (f"timing: compile={t['compile_s']:.2f}s "
            f"({t['compile_steps']} traced steps) "
            f"steady_step={t['steady_step_s'] * 1e3:.2f}ms "
            f"over {t['steady_steps']} steps")


def _warm_sync(eng, cfg, batch_size, max_prompt):
    """Compile prefill/serve at the shapes the traffic will hit (a chunk's
    padded length is its longest prompt, so warm at max_prompt). Retraces on
    odd-shaped partial chunks remain — a genuine synchronized-engine cost."""
    prompts = [np.zeros(max_prompt, np.int32)] * batch_size
    eng.generate(prompts, GenerationConfig(max_new_tokens=1))


def run_sync(cfg, params, traffic, batch_size, max_prompt, max_new):
    """Synchronized baseline under the same arrival process (the paper's
    §5.3.2 setting, extended with arrival-time accounting). The engine's own
    convoy scheduler does the waiting: ``_ready()`` holds a batch until it
    fills (or the trace is exhausted), and per-request budgets/EOS are
    honored inside the decode loop — no driver-side chunking needed."""
    # exact_moe matches the continuous engine's dispatch setting so the
    # headline ratio measures scheduling, not a capacity handicap
    eng = ServingEngine(cfg, params, batch_size=batch_size,
                        max_prompt_len=max_prompt, max_new_tokens=max_new,
                        exact_moe=True)
    _warm_sync(eng, cfg, batch_size, max_prompt)
    t0 = time.perf_counter()
    res = eng.generate_timed(traffic)
    wall = time.perf_counter() - t0
    tokens = sum(len(r.tokens) for r in res)
    latencies = [r.latency_s for r in res]
    return tokens / wall, latencies, wall, eng


def _run_timed(eng, traffic, max_prompt):
    """Warm (compile at the traffic's fixed shapes), reset stats, replay."""
    eng.generate([np.zeros(max_prompt, np.int32)],
                 GenerationConfig(max_new_tokens=1))
    eng.reset_stats()
    t0 = time.perf_counter()
    res = eng.generate_timed(traffic)
    wall = time.perf_counter() - t0
    tokens = sum(len(r.tokens) for r in res)
    latencies = [r.latency_s for r in res]
    return tokens / wall, latencies, wall, eng


def run_continuous(cfg, params, traffic, slots, max_prompt, max_new):
    eng = ContinuousBatchingEngine(cfg, params, n_slots=slots,
                                   max_prompt_len=max_prompt,
                                   max_new_tokens=max_new)
    return _run_timed(eng, traffic, max_prompt)


def run_paged(cfg, params, traffic, slots, max_prompt, max_new,
              page_size, chunk_size):
    eng = PagedEngine(cfg, params, n_slots=slots, page_size=page_size,
                      chunk_size=chunk_size, max_prompt_len=max_prompt,
                      max_new_tokens=max_new)
    return _run_timed(eng, traffic, max_prompt)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b-lite")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--prompt-lens", default="8,24,48")
    ap.add_argument("--out-lens", default="4,12,24")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    prompt_lens = [int(x) for x in args.prompt_lens.split(",")]
    out_lens = [int(x) for x in args.out_lens.split(",")]
    max_prompt, max_new = max(prompt_lens), max(out_lens)
    traffic = make_traffic(cfg, args.requests, args.rate, prompt_lens,
                           out_lens, args.seed)
    span = traffic[-1][0]
    print(f"# {args.requests} requests over {span:.2f}s "
          f"(rate {args.rate}/s), prompts {prompt_lens}, outputs {out_lens}")

    tps_c, lat_c, wall_c, eng = run_continuous(
        cfg, params, traffic, args.slots, max_prompt, max_new)
    m, p95 = lat_stats(lat_c)
    print(f"continuous  ({args.slots} slots): {tps_c:6.1f} tok/s  "
          f"latency mean {m:.2f}s p95 {p95:.2f}s  wall {wall_c:.2f}s")
    from common import moe_overflow
    print(f"  scheduler: admitted={eng.n_admitted} "
          f"decode_steps={eng.decode_steps} "
          f"max_concurrency={eng.max_concurrency} "
          f"traces(prefill={eng.prefill_traces}, decode={eng.decode_traces}) "
          f"moe_overflow={moe_overflow(eng)}")
    print(f"  {timing_line(eng)}")

    tps_p, lat_p, wall_p, peng = run_paged(
        cfg, params, traffic, args.slots, max_prompt, max_new,
        args.page_size, args.chunk_size)
    m, p95 = lat_stats(lat_p)
    print(f"paged       ({args.slots} slots): {tps_p:6.1f} tok/s  "
          f"latency mean {m:.2f}s p95 {p95:.2f}s  wall {wall_p:.2f}s")
    print(f"  scheduler: admitted={peng.n_admitted} "
          f"chunk_steps={peng.chunk_steps} "
          f"decode_steps={peng.decode_steps} "
          f"prefix_hit_rate={peng.prefix_hit_rate:.2f} "
          f"traces(chunk={peng.chunk_traces}, decode={peng.decode_traces}) "
          f"moe_overflow={moe_overflow(peng)}")
    print(f"  {timing_line(peng)}")

    tps_s, lat_s, wall_s, seng = run_sync(cfg, params, traffic, args.slots,
                                          max_prompt, max_new)
    m, p95 = lat_stats(lat_s)
    print(f"synchronized (B={args.slots})  : {tps_s:6.1f} tok/s  "
          f"latency mean {m:.2f}s p95 {p95:.2f}s  wall {wall_s:.2f}s")
    print(f"  {timing_line(seng)}")
    print(f"# continuous/synchronized throughput: {tps_c / tps_s:.2f}x, "
          f"mean-latency: {lat_stats(lat_c)[0] / lat_stats(lat_s)[0]:.2f}x")


if __name__ == "__main__":
    main()
