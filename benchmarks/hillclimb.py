"""Perf-iteration harness: recompile one (arch x shape) with experimental
overrides and print the roofline terms + collective breakdown.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch qwen3-moe-30b-a3b \
        --shape train_4k [--no-remat] [--moe-impl gspmd] ...

Each run = one hypothesis->change->measure cycle for EXPERIMENTS.md §Perf.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time

import jax

from repro.configs import INPUT_SHAPES, get_config
from repro.launch import mesh as mesh_mod
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
from repro.launch import dryrun as dr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--moe-impl", default=None, choices=["setp", "gspmd"])
    ap.add_argument("--no-dualsparse", action="store_true")
    ap.add_argument("--dump-hlo", default=None)
    ap.add_argument("--label", default="exp")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = INPUT_SHAPES[args.shape]
    mesh = mesh_mod.make_production_mesh(multi_pod=False)

    # monkey-patch build_dist with overrides
    orig = dr.build_dist

    def patched(cfg_, kind, mesh_):
        d = orig(cfg_, kind, mesh_)
        kw = {}
        if args.no_remat:
            kw["remat"] = False
        if args.remat_policy:
            kw["remat_policy"] = args.remat_policy
        if args.moe_impl:
            kw["moe_impl"] = args.moe_impl
        if args.no_dualsparse:
            kw["dualsparse"] = False
            kw["load_aware"] = False
        return dataclasses.replace(d, **kw) if kw else d

    dr.build_dist = patched
    t0 = time.time()
    a, sh, step = dr.abstract_state(cfg, shape, mesh, cfg.dualsparse.enabled)
    jitted = jax.jit(step, in_shardings=sh)
    with jax.set_mesh(mesh):
        comp = jitted.lower(*a).compile()
    c = analyze_hlo(comp.as_text())
    try:
        ma = comp.memory_analysis()
        temp = ma.temp_size_in_bytes
        if shape.kind != "train":
            # remove the CPU FloatNormalization f32-weight-copy artifact
            temp = max(temp - 2 * dr._per_device_param_bytes(a[0], sh[0]), 0)
        traffic = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + 2 * temp)
    except Exception:
        traffic, temp = 0, 0
    rt = roofline_terms(c.flops, traffic, c.collective_bytes, 1,
                        peak_flops=mesh_mod.PEAK_FLOPS_BF16,
                        hbm_bw=mesh_mod.HBM_BW, ici_bw=mesh_mod.ICI_BW)
    if args.dump_hlo:
        open(args.dump_hlo, "w").write(comp.as_text())
    print(json.dumps({
        "label": args.label, "arch": args.arch, "shape": args.shape,
        "compile_s": round(time.time() - t0, 1),
        "flops": c.flops, "hbm_traffic": traffic, "temp_bytes": temp,
        "coll_bytes": c.collective_bytes,
        "by_kind": c.bytes_by_kind, "count_by_kind": c.count_by_kind,
        "roofline": rt,
    }, indent=1))


if __name__ == "__main__":
    main()
