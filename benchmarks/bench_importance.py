"""Paper §5.3.4 (Fig 13): neuron-importance profiling method comparison —
which of the four metrics (Eqs. 14-17) yields the lowest 2T-Drop error."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import drop, gating, moe, reconstruct
from repro.data import pipeline
from repro.models.layers import split_params

from .common import Row, rel_err, sharp_router_params


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(6)
    for name in ("mixtral-8x7b-lite", "dsv2-lite-lite"):
        cfg = get_config(name)
        params, _ = split_params(moe.make_moe_params(key, cfg))
        params = sharp_router_params(params)
        calib = pipeline.calibration_activations(jax.random.fold_in(key, 1),
                                                 512, cfg.d_model)
        x = pipeline.calibration_activations(jax.random.fold_in(key, 2),
                                             512, cfg.d_model)
        y0 = moe.moe_forward_ref(params, x, cfg)
        r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
        t1 = float(jnp.quantile(r.norm_score, 0.25))
        gap = max(min(0.01, t1 * 0.2), 1e-4)
        pairs = drop.expand_pairs_2t(r.idx, r.combine, r.norm_score, 2,
                                     t1 - gap, t1 + gap)
        for method in reconstruct.IMPORTANCE_METHODS:
            rec = reconstruct.partition_and_reconstruct(params, calib, cfg,
                                                        p=2, method=method)
            y = moe.moe_forward_ref(rec, x, cfg, pairs=pairs)
            rows.append((f"importance/{name}/{method}", 0.0,
                         f"rel_err={rel_err(y, y0):.4f}"))
    return rows
