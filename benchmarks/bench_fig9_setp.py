"""Paper Fig 9: S-ETP vs ETP communication. We count exact collective bytes
and ops from the compiled HLO (the TPU analogue of the paper's NCCL
bandwidth test) for the paper's real-world configs (E2T4 / E4T2 on 8
devices) and simulated NVL72 (EP9xTP8) / CloudMatrix384 (EP48xTP8).

Runs in subprocesses because each mesh needs its own
--xla_force_host_platform_device_count."""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import Row

_PROG = r"""
import dataclasses, json, sys
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core import moe, setp
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_mesh_auto, use_mesh
from repro.models.layers import split_params

ep, tp, tokens = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3])
# expert count must tile the EP axis (paper's simulated meshes put whole
# experts on EP ranks): E = ep * ceil(8/ep)
E = ep * max(1, (8 + ep - 1) // ep)
cfg = dataclasses.replace(get_config("mixtral-8x7b-lite"), n_experts=E)
key = jax.random.PRNGKey(0)
params, _ = split_params(moe.make_moe_params(key, cfg))
x = jax.ShapeDtypeStruct((ep, tokens, cfg.d_model), jnp.float32)

# ETP: EP x TP mesh
mesh = make_mesh_auto((ep, tp), ("ep", "tp"))
with use_mesh(mesh):
    comp = jax.jit(lambda p, xx: setp.etp_moe_forward(
        p, xx, cfg, mesh, cap_factor=1.5)).lower(params, x).compile()
etp = analyze_hlo(comp.as_text())

# S-ETP: partial transform P=tp, pure EP over ep*tp devices, expressed as
# a keep-everything 2T policy with partition factor P=tp
p_factor = tp
pp = setp.place_params_strided(
    __import__("repro.core.partition", fromlist=["partial_transform"])
    .partial_transform(params, p_factor), ep * tp)
mesh2 = make_mesh_auto((1, ep * tp), ("data", "model"))
from repro.core.policy import TwoTDrop
pol = TwoTDrop(partition_p=p_factor, t_major=-1.0, t_minor=-1.0)
x2 = jax.ShapeDtypeStruct((1, ep * tokens, cfg.d_model), jnp.float32)
with use_mesh(mesh2):
    comp2 = jax.jit(lambda p, xx: setp.setp_moe_forward(
        p, xx, cfg, mesh2, policy=pol, cap_factor=1.5,
        cap_multiple=1)).lower(pp, x2).compile()
s_etp = analyze_hlo(comp2.as_text())

print(json.dumps({"etp": etp.bytes_by_kind, "etp_total": etp.collective_bytes,
                  "setp": s_etp.bytes_by_kind,
                  "setp_total": s_etp.collective_bytes}))
"""

CONFIGS = [
    ("E2T4", 2, 4, 512),
    ("E4T2", 4, 2, 512),
    ("NVL72", 9, 8, 512),
    ("CM384", 48, 8, 512),
]


def run() -> list[Row]:
    rows: list[Row] = []
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for name, ep, tp, tokens in CONFIGS:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{ep * tp}")
        env["PYTHONPATH"] = os.path.join(root, "src")
        p = subprocess.run([sys.executable, "-c", _PROG, str(ep), str(tp),
                            str(tokens)], capture_output=True, text=True,
                           env=env, timeout=900)
        if p.returncode != 0:
            rows.append((f"fig9/{name}", 0.0, f"ERROR {p.stderr[-200:]}"))
            continue
        res = json.loads(p.stdout.strip().splitlines()[-1])
        ratio = res["etp_total"] / max(res["setp_total"], 1)
        rows.append((
            f"fig9/{name}(EP{ep}xTP{tp})", 0.0,
            f"etp_bytes={res['etp_total']:.3g} setp_bytes="
            f"{res['setp_total']:.3g} reduction={ratio:.2f}x "
            f"setp_kinds={sorted(res['setp'])} etp_kinds={sorted(res['etp'])}"
        ))
    return rows
