"""Paper Fig 10: drop rate -> actual speedup. The paper's point: tensor-
granular dropping converts directly into GEMM-size reduction. Here the
dispatch buffers (and the Pallas kernel's live block count) shrink with the
post-drop capacity; we time the jitted MoE layer at several drop rates on
CPU and report wall-time speedup alongside the FLOPs-saved fraction."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import drop, gating, moe, reconstruct
from repro.data import pipeline
from repro.models.layers import split_params

from .common import Row, sharp_router_params, time_fn


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(3)
    cfg = get_config("olmoe-lite")
    params, _ = split_params(moe.make_moe_params(key, cfg))
    params = sharp_router_params(params)
    x = pipeline.calibration_activations(key, 2048, cfg.d_model)
    rec = reconstruct.partition_and_reconstruct(params, x, cfg, p=2)
    rec["wg"] = params["wg"]
    r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)

    base_us = None
    for target in (0.0, 0.1, 0.25, 0.4):
        t1 = float(jnp.quantile(r.norm_score, target)) if target else -1.0
        gap = max(min(0.01, t1 * 0.2), 1e-4)
        pairs = moe.route_dualsparse(rec, x, cfg,
                                     thresholds=(t1 - gap, t1 + gap))
        fs = float(drop.flops_saved_fraction(pairs.modes))
        # capacity sized to the post-drop load (what a real deployment does)
        cap = moe.capacity_for(x.shape[0], pairs.idx.shape[1],
                               rec["w1"].shape[0],
                               capacity_factor=1.25 * max(1 - fs, 0.05))

        @jax.jit
        def layer(p, xx):
            pr = moe.route_dualsparse(p, xx, cfg,
                                      thresholds=(t1 - gap, t1 + gap))
            return moe.moe_forward_dispatch(p, xx, cfg, pairs=pr,
                                            capacity=cap)

        us = time_fn(layer, rec, x, iters=5)
        if base_us is None:
            base_us = us
        rows.append((f"fig10/drop{target:.2f}", us,
                     f"flops_saved={fs:.3f} speedup={base_us / us:.2f}x"))
    return rows
