"""Paper Fig 10: drop rate -> actual speedup. The paper's point: tensor-
granular dropping converts directly into GEMM-size reduction. Here the
dispatch buffers (and the Pallas kernel's live block count) shrink with the
post-drop capacity; we time the jitted MoE layer at several drop rates on
CPU and report wall-time speedup alongside the FLOPs-saved fraction.

Expressed as a SparsityPolicy sweep: one ``TwoTDrop`` per target drop rate,
thresholds calibrated by ``policy.prepare`` (rate-space band around the
target); the baseline is the keep-everything 2T policy."""
from __future__ import annotations

import jax

from repro.configs import get_config
from repro.core import drop, moe
from repro.core.policy import TwoTDrop
from repro.data import pipeline
from repro.models.layers import split_params

from .common import Row, sharp_router_params, time_fn


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(3)
    cfg = get_config("olmoe-lite")
    params, _ = split_params(moe.make_moe_params(key, cfg))
    params = sharp_router_params(params)
    x = pipeline.calibration_activations(key, 2048, cfg.d_model)

    # prepare (partition + reconstruction) ONCE; each sweep point only
    # re-calibrates thresholds against the shared prepared params
    keep_all = TwoTDrop(partition_p=2, t_major=-1.0, t_minor=-1.0)
    rec, keep_all = keep_all.prepare(params, cfg, x)
    sweep = [("drop0.00", keep_all)]
    sweep += [(f"drop{t:.2f}",
               TwoTDrop(partition_p=2, drop_target=t).calibrate(rec, cfg, x))
              for t in (0.1, 0.25, 0.4)]

    base_us = None
    for label, pol in sweep:
        pairs = pol.route(rec, x, cfg)
        fs = float(drop.flops_saved_fraction(pairs.modes))
        # capacity sized to the post-drop load (what a real deployment does)
        cap = moe.capacity_for(x.shape[0], pairs.idx.shape[1],
                               rec["w1"].shape[0],
                               capacity_factor=1.25 * max(1 - fs, 0.05))

        @jax.jit
        def layer(p, xx, pol=pol, cap=cap):
            pr = pol.route(p, xx, cfg)
            return moe.moe_forward_dispatch(p, xx, cfg, pairs=pr,
                                            capacity=cap)

        us = time_fn(layer, rec, x, iters=5)
        if base_us is None:
            base_us = us
        rows.append((f"fig10/{label}", us,
                     f"flops_saved={fs:.3f} speedup={base_us / us:.2f}x"))
    return rows
