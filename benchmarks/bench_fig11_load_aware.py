"""Paper Fig 11: load-aware thresholding under EP. With skewed routing, the
EP step time is the max device load (makespan). We compare:

  no-drop / 1T / 2T / 2T+load-aware

on makespan speedup (proxy for the paper's 1.41x MoE speedup) and output
error (accuracy proxy), at the same T_max."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import drop, gating, load_aware, moe, reconstruct
from repro.data import pipeline
from repro.models.layers import split_params

from .common import Row, rel_err, sharp_router_params


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(4)
    cfg = get_config("olmoe-lite")
    params, _ = split_params(moe.make_moe_params(key, cfg))
    params = sharp_router_params(params, 20.0)
    # skew the router so a few experts (hence one EP device) are hot
    skew = jnp.where(jnp.arange(cfg.n_experts) < cfg.n_experts // 8, 2.0, 0.0)
    params["wg"] = params["wg"] + skew[None, :] * 0.05
    x = pipeline.calibration_activations(key, 2048, cfg.d_model)
    y0 = moe.moe_forward_ref(params, x, cfg)
    rec = reconstruct.partition_and_reconstruct(params, x, cfg, p=2)

    D = 8                                     # EP devices
    E_sub = cfg.n_experts * 2
    per_dev = E_sub // D
    r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
    t_max = float(jnp.quantile(r.norm_score, 0.3))
    gap = max(min(0.01, t_max * 0.2), 1e-4)

    base = drop.expand_pairs_2t(r.idx, r.combine, r.norm_score, 2, -1., -1.)
    dev_of = base.idx % D                      # strided placement

    def stats(pairs, label):
        hist = jax.vmap(lambda d, k: jnp.zeros(D).at[d].add(
            k.astype(jnp.float32)), in_axes=(0, 0))(dev_of, pairs.keep)
        loads = hist.sum(0)
        y = moe.moe_forward_ref(rec, x, cfg, pairs=pairs)
        return loads, rel_err(y, y0)

    loads0, _ = stats(base, "none")
    ms0 = float(load_aware.makespan(loads0))

    # 1T uniform
    keep = jnp.repeat(drop.one_t_keep(r.norm_score, t_max)[:, :, None], 2,
                      2).reshape(base.keep.shape)
    p1 = base._replace(keep=keep)
    l1, e1 = stats(p1, "1t")

    # 2T uniform
    p2 = drop.expand_pairs_2t(r.idx, r.combine, r.norm_score, 2,
                              t_max - gap, t_max + gap)
    l2, e2 = stats(p2, "2t")

    # 2T + load-aware: per-device thresholds from pre-drop loads
    t_dev = load_aware.step_down_thresholds(loads0, t_max)
    t1_pair = t_dev[dev_of]
    is_major = (base.idx % 2) == 0
    keep_la = jnp.where(is_major,
                        jnp.repeat(r.norm_score[:, :, None], 2, 2).reshape(
                            base.keep.shape) > t1_pair - gap,
                        jnp.repeat(r.norm_score[:, :, None], 2, 2).reshape(
                            base.keep.shape) >= t1_pair + gap)
    pla = base._replace(keep=keep_la)
    lla, ela = stats(pla, "2t+la")

    for label, loads, err, pairs in [("1T", l1, e1, p1), ("2T", l2, e2, p2),
                                     ("2T+load-aware", lla, ela, pla)]:
        ms = float(load_aware.makespan(loads))
        dr = float(drop.drop_rate(pairs))
        rows.append((f"fig11/{label}", 0.0,
                     f"moe_speedup={ms0 / ms:.2f}x drop_rate={dr:.3f} "
                     f"rel_err={err:.4f}"))
    return rows
