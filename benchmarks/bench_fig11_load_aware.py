"""Paper Fig 11: load-aware thresholding under EP. With skewed routing, the
EP step time is the max device load (makespan). A registry sweep over the
drop policies —

  1t / 2t / load_aware   (vs. the keep-everything baseline)

— compares makespan speedup (proxy for the paper's 1.41x MoE speedup) and
output error (accuracy proxy), at the same T_max."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import drop, gating, load_aware, moe
from repro.core.policy import LoadAwareTwoT, OneTDrop, TwoTDrop
from repro.data import pipeline
from repro.models.layers import split_params

from .common import Row, rel_err, sharp_router_params


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(4)
    cfg = get_config("olmoe-lite")
    params, _ = split_params(moe.make_moe_params(key, cfg))
    params = sharp_router_params(params, 20.0)
    # skew the router so a few experts (hence one EP device) are hot
    skew = jnp.where(jnp.arange(cfg.n_experts) < cfg.n_experts // 8, 2.0, 0.0)
    params["wg"] = params["wg"] + skew[None, :] * 0.05
    x = pipeline.calibration_activations(key, 2048, cfg.d_model)
    y0 = moe.moe_forward_ref(params, x, cfg)

    D = 8                                     # EP devices (contiguous blocks)
    per_dev = cfg.n_experts // D
    r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
    t_max = float(jnp.quantile(r.norm_score, 0.3))
    gap = max(min(0.01, t_max * 0.2), 1e-4)

    baseline = TwoTDrop(partition_p=2, t_major=-1.0, t_minor=-1.0)
    sweep = [
        ("1T", OneTDrop(partition_p=2, t_drop=t_max)),
        ("2T", TwoTDrop(partition_p=2, t_major=t_max - gap,
                        t_minor=t_max + gap)),
        ("2T+load-aware", LoadAwareTwoT(partition_p=2, n_devices=D,
                                        t_max=t_max, t_gap=gap)),
    ]

    rec, _ = baseline.prepare(params, cfg, x)

    def stats(pairs):
        # device of a sub-pair via its ORIGINAL expert (contiguous layout,
        # matching LoadAwareTwoT's dispatch-path model)
        dev_of = (pairs.idx // 2) // per_dev
        hist = jax.vmap(lambda d, k: jnp.zeros(D).at[d].add(
            k.astype(jnp.float32)), in_axes=(0, 0))(dev_of, pairs.keep)
        loads = hist.sum(0)
        y = moe.moe_forward_ref(rec, x, cfg, pairs=pairs)
        return loads, rel_err(y, y0)

    loads0, _ = stats(baseline.route(rec, x, cfg))
    ms0 = float(load_aware.makespan(loads0))

    for label, pol in sweep:
        pairs = pol.route(rec, x, cfg)
        loads, err = stats(pairs)
        ms = float(load_aware.makespan(loads))
        dr = float(drop.drop_rate(pairs))
        rows.append((f"fig11/{label}", 0.0,
                     f"moe_speedup={ms0 / ms:.2f}x drop_rate={dr:.3f} "
                     f"rel_err={err:.4f}"))
    return rows
