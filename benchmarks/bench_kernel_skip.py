"""Pallas dualsparse_ffn tile-skip accounting: for realistic routing at
several drop rates, the exact fraction of (token-block × neuron-block) MXU
tiles the kernel's ``pl.when`` gate never issues — the hardware-level
realization of paper Fig 10 ("drop rates translate directly into speedup").

Computed analytically from the same counts the kernel receives (no interpret-
mode timing noise): a tile (e, c, f) is live iff
    c*block_c < counts_full[e] + counts_major[e]   (major-half tiles)
    c*block_c < counts_full[e]                     (minor-half tiles)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import drop, gating, moe, reconstruct
from repro.data import pipeline
from repro.models.layers import split_params

from .common import Row, sharp_router_params


def tile_skip_fraction(counts_full, counts_major, C, f, block_c=128,
                       block_f=128):
    E = counts_full.shape[0]
    nc = -(-C // block_c)
    nf = -(-f // block_f)
    c0 = np.arange(nc) * block_c
    f0 = np.arange(nf) * block_f
    live = 0
    for e in range(E):
        for fi in f0:
            rows = counts_full[e] + counts_major[e] if fi < f // 2 \
                else counts_full[e]
            live += int(np.sum(c0 < rows))
    return 1.0 - live / (E * nc * nf)


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(8)
    cfg = get_config("olmoe-lite")
    params, _ = split_params(moe.make_moe_params(key, cfg))
    params = sharp_router_params(params)
    x = pipeline.calibration_activations(key, 4096, cfg.d_model)
    rec = reconstruct.partition_and_reconstruct(params, x, cfg, p=2)
    rec["wg"] = params["wg"]
    r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
    from repro.core import dispatch as dispatch_mod
    for target in (0.0, 0.1, 0.25, 0.4):
        t1 = float(jnp.quantile(r.norm_score, target)) if target else -1.0
        gap = max(min(0.01, t1 * 0.2), 1e-4)
        pairs = moe.route_dualsparse(rec, x, cfg,
                                     thresholds=(t1 - gap, t1 + gap))
        # the PRODUCTION kernel layout (moe_forward_dispatch use_kernel +
        # mode grouping): one buffer per ORIGINAL expert of full width
        # d_expert, FULL rows then MAJOR-only rows, minor-half tiles of the
        # MAJOR-only tail skipped via counts_major
        fused = dispatch_mod.fuse_sub_pairs(pairs, 2)
        counts = np.asarray(dispatch_mod.group_histogram(
            fused.group, cfg.n_experts, mask=fused.keep))
        C = int(np.ceil(max(int(counts.max()), 1) / 8) * 8)
        plan = dispatch_mod.sort_dispatch(fused.group, fused.keep,
                                          n_groups=cfg.n_experts, capacity=C,
                                          major_only=fused.major_only)
        cf, cm = (np.asarray(a) for a in plan.kernel_counts(C))
        skip = tile_skip_fraction(cf, cm, C, cfg.d_expert,
                                  block_c=32, block_f=64)
        fs = float(drop.flops_saved_fraction(pairs.modes))
        rows.append((f"kernel_skip/drop{target:.2f}", 0.0,
                     f"flops_saved={fs:.3f} mxu_tiles_skipped={skip:.3f} "
                     f"(capacity C={C} major_only_rows={int(cm.sum())})"))
    return rows
