"""Fused MoE pipeline benchmark + HBM-elimination assertion.

Compares the production buffer path (gather_rows -> grouped_swiglu ->
unpermute + combine) against the single fused Pallas pipeline
(``fused_pipeline=True``: the kernel consumes the DispatchPlan directly) on
the same 2T-routed layer, and — the part CI gates on — lowers both to HLO
and asserts via ``launch.hlo_analysis`` that the fused path materializes NO
``(E, capacity, d)`` intermediate buffer (the two HBM round-trips the fused
kernel exists to eliminate; see README "Dispatch architecture").

Timings on this CPU container run the kernels in interpret mode, so the
µs numbers track *plan/dispatch overhead*, not MXU economics — the HLO
bytes/shape accounting is the backend-independent signal. Full runs add
prefill-scale rows (T=4096/8192) that compare buffer vs resident-fused vs
streamed-fused and gate streamed <= buffer; streamed must match resident
bit-for-bit at every scale.

Emits/APPENDS to ``BENCH_moe_pipeline.json`` (repo root by default): the
file holds a ``runs`` list — one entry per invocation — so the trajectory
accumulates across PRs instead of overwriting. Schema documented in README.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_moe_pipeline [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs import get_config
from repro.core import moe as moe_mod
from repro.core import policy as policy_mod
from repro.launch import hlo_analysis
from repro.lint.bench_schema import validate_pipeline_bench
from repro.lint.hlo_passes import capacity_buffer_count
from repro.models.layers import split_params

from .common import Row, rel_err, sharp_router_params, time_fn

# Full runs include prefill-scale rows: at T >= PREFILL_T the resident
# fused kernel would need the whole (T, d) activation + f32 accumulator in
# VMEM, so these rows are the ones that exercise (and gate) the streamed
# HBM<->VMEM DMA rewrite. Interpret mode makes them slow — iters drops to
# PREFILL_ITERS there.
FULL_TOKENS = [128, 256, 4096, 8192]
SMOKE_TOKENS = [64]
PREFILL_T = 4096
PREFILL_ITERS = 2


def _setup(seed: int = 0):
    cfg = get_config("olmoe-lite").reduced()
    key = jax.random.PRNGKey(seed)
    params, _ = split_params(moe_mod.make_moe_params(key, cfg))
    params = sharp_router_params(params)
    policy = policy_mod.make_policy("2t", cfg.dualsparse, use_kernel=True)
    calib = jax.random.normal(jax.random.fold_in(key, 1), (96, cfg.d_model))
    params, policy = policy.prepare(params, cfg, calib)
    return cfg, params, policy


def _paths(cfg, params, policy, T: int):
    """(buffer_fn, fused_fn, resident_fn, x, capacity) — jitted, same
    routing inside. ``fused_fn`` is the streamed kernel (the production
    default); ``resident_fn`` is the whole-array-resident variant it
    replaced, kept as the bit-exactness yardstick for the DMA machinery."""
    E = params["w1"].shape[0] // policy.partition_p
    capacity = moe_mod.capacity_for(T, cfg.top_k, E, policy.capacity_factor)

    def run(x, fused: bool, streamed: bool = True):
        pairs = policy.route(params, x, cfg)
        return moe_mod.moe_forward_dispatch(
            params, x, cfg, pairs=pairs, capacity=capacity,
            use_kernel=not fused, mode_grouped=policy.kernel_mode_grouping,
            fused_pipeline=fused, fused_streamed=streamed,
            return_overflow=True)

    x = jax.random.normal(jax.random.PRNGKey(T), (T, cfg.d_model))
    buffer_fn = jax.jit(lambda x: run(x, False))
    fused_fn = jax.jit(lambda x: run(x, True))
    resident_fn = jax.jit(lambda x: run(x, True, streamed=False))
    return buffer_fn, fused_fn, resident_fn, x, capacity


def run(smoke: bool = False, out_path: str | None = None) -> list[Row]:
    cfg, params, policy = _setup()
    E = params["w1"].shape[0] // policy.partition_p
    d = cfg.d_model
    rows: list[Row] = []
    results = []
    for T in (SMOKE_TOKENS if smoke else FULL_TOKENS):
        iters = PREFILL_ITERS if T >= PREFILL_T else (2 if smoke else 5)
        buffer_fn, fused_fn, resident_fn, x, capacity = _paths(
            cfg, params, policy, T)

        yb, ovb = buffer_fn(x)
        yf, ovf = fused_fn(x)
        yr, ovr = resident_fn(x)
        # streamed and resident share math and accumulation order; the DMA
        # staging must not perturb a single bit.
        assert (yf == yr).all() and int(ovf) == int(ovr), (
            f"streamed kernel diverged from resident variant at T={T}")
        err = rel_err(yf, yb)
        assert err <= 1e-6, f"fused path diverged from oracle: rel_err={err}"
        assert int(ovb) == int(ovf), (
            f"overflow units differ: buffer={int(ovb)} fused={int(ovf)}")

        hlo_b = buffer_fn.lower(x).compile().as_text()
        hlo_f = fused_fn.lower(x).compile().as_text()
        nb = capacity_buffer_count(hlo_b, E, capacity, d)
        nf = capacity_buffer_count(hlo_f, E, capacity, d)
        assert nb > 0, (
            f"buffer path shows no (E={E}, C={capacity}, d={d}) "
            "intermediate — the assertion target moved; update the bench")
        assert nf == 0, (
            f"REGRESSION: fused path materializes {nf} (E={E}, "
            f"C={capacity}, d={d}) capacity buffer(s) — the HBM round-trip "
            "the fused pipeline exists to eliminate is back")
        cb = hlo_analysis.analyze_hlo(hlo_b)
        cf = hlo_analysis.analyze_hlo(hlo_f)

        t_buf = time_fn(buffer_fn, x, iters=iters, warmup=1)
        t_fus = time_fn(fused_fn, x, iters=iters, warmup=1)
        t_res = time_fn(resident_fn, x, iters=iters, warmup=1)
        if T >= PREFILL_T:
            assert t_fus <= t_buf, (
                f"REGRESSION: streamed fused pipeline slower than buffer "
                f"path at prefill scale T={T}: fused={t_fus:.0f}us "
                f"buffer={t_buf:.0f}us")
        tag = f"moe_pipeline/T{T}_E{E}_cap{capacity}"
        rows.append((f"{tag}/buffer", t_buf,
                     f"hbm_bytes={cb.hbm_bytes:.0f} cap_bufs={nb}"))
        rows.append((f"{tag}/fused", t_fus,
                     f"hbm_bytes={cf.hbm_bytes:.0f} cap_bufs=0 "
                     f"rel_err={err:.2e}"))
        rows.append((f"{tag}/resident", t_res, "bit-exact vs fused"))
        results.append({
            "T": T, "E": E, "d": d, "f": cfg.d_expert,
            "K": cfg.top_k, "P": policy.partition_p, "capacity": capacity,
            "buffer_us": t_buf, "fused_us": t_fus, "resident_us": t_res,
            "streamed": True,
            "buffer_hbm_bytes": cb.hbm_bytes, "fused_hbm_bytes": cf.hbm_bytes,
            "buffer_capacity_buffers": nb, "fused_capacity_buffers": nf,
            "rel_err_vs_oracle": err, "overflow_pairs": int(ovb),
        })

    run_entry = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "host": {"backend": jax.default_backend(),
                 "devices": jax.device_count()},
        "smoke": smoke,
        "rows": results,
    }
    out_path = out_path or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_moe_pipeline.json")
    payload = {
        "bench": "moe_pipeline",
        "unit": "us_per_layer_forward",
        "note": "buffer path (gather_rows -> grouped_swiglu -> unpermute) "
                "vs single fused Pallas pipeline (fused_us = streamed "
                "kernel; resident_us = whole-array-resident variant, "
                "bit-exact vs streamed); capacity_buffers counts "
                "(E, capacity, d)-shaped HLO instructions (must be 0 on "
                "the fused path); interpret-mode timings on CPU",
        "runs": [],
    }
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                old = json.load(f)
            if isinstance(old.get("runs"), list):
                payload["runs"] = old["runs"]
        except (json.JSONDecodeError, OSError):
            pass
    payload["runs"].append(run_entry)
    schema_errs = validate_pipeline_bench(payload)
    assert not schema_errs, (
        "refusing to write a malformed BENCH_moe_pipeline.json: "
        + "; ".join(schema_errs))
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single tiny shape for CI (seconds)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()
    t0 = time.time()
    rows = run(smoke=args.smoke, out_path=args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"# moe_pipeline bench done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
