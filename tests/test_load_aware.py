"""Paper §4.3: load-aware thresholding in EP."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import load_aware


def test_device_loads():
    hist = jnp.arange(8)           # 8 experts
    loads = load_aware.device_loads(hist, 2)   # 4 devices
    np.testing.assert_array_equal(np.asarray(loads), [1, 5, 9, 13])


def test_step_down_rule():
    loads = jnp.array([10., 20., 30., 40.])    # ideal = 25
    t = load_aware.step_down_thresholds(loads, t_max=0.1)
    np.testing.assert_allclose(np.asarray(t),
                               [0.1 * 10 / 25, 0.1 * 20 / 25, 0.1, 0.1],
                               rtol=1e-6)


def test_overloaded_devices_get_t_max():
    loads = jnp.array([100., 1., 1., 1.])
    t = load_aware.step_down_thresholds(loads, 0.2)
    np.testing.assert_allclose(float(t[0]), 0.2, rtol=1e-6)
    assert np.all(np.asarray(t[1:]) < 0.02)


def test_pair_thresholds_follow_device(rng):
    loads = jnp.array([10., 40.])              # dev1 overloaded
    idx = jnp.array([[0, 3]])                  # expert 0 -> dev0, 3 -> dev1
    t_major, t_minor = load_aware.pair_thresholds(idx, loads, 2, t_max=0.1)
    assert float(t_major[0, 0]) < float(t_major[0, 1])
    np.testing.assert_allclose(np.asarray(t_minor - t_major), 0.02,
                               atol=1e-6)


def test_load_aware_drops_less_at_same_makespan(rng):
    """Core §4.3 property: vs. a uniform T_max threshold, step-down
    thresholds drop FEWER pairs while the post-drop makespan (max device
    load) does not exceed the uniform policy's."""
    D, E_per, T, K = 4, 4, 4096, 2
    E = D * E_per
    k1, k2 = jax.random.split(rng)
    # skewed routing: device 0 heavily loaded
    logits = jax.random.normal(k1, (T, E)) + jnp.where(
        jnp.arange(E) < E_per, 1.5, 0.0)
    from repro.core import gating
    r = gating.top_k_routing(logits, K, renorm=True)
    hist = gating.expert_histogram(r.idx, E)
    loads = load_aware.device_loads(hist, E_per)
    t_max = 0.45

    dev_of = r.idx // E_per
    # uniform threshold policy
    keep_uniform = r.norm_score > t_max
    # load-aware step-down policy
    t_dev = load_aware.step_down_thresholds(loads, t_max)
    keep_la = r.norm_score > t_dev[dev_of]

    def post_loads(keep):
        h = gating.expert_histogram(r.idx, E, keep=keep)
        return load_aware.device_loads(h, E_per)

    ms_uniform = float(load_aware.makespan(post_loads(keep_uniform)))
    ms_la = float(load_aware.makespan(post_loads(keep_la)))
    dropped_uniform = float(1 - keep_uniform.mean())
    dropped_la = float(1 - keep_la.mean())
    assert dropped_la < dropped_uniform
    assert ms_la <= ms_uniform * 1.02


def test_load_aware_dtypes_pinned_under_x64():
    """Regression for the f32-explicit histogram math: an int histogram
    divided/averaged without the explicit casts would promote to f64 under
    jax_enable_x64 (the lint's calib/load_aware entry checks the trace)."""
    with jax.experimental.enable_x64():
        hist = jnp.arange(8, dtype=jnp.int32)
        loads = load_aware.device_loads(hist, 2)
        ts = load_aware.step_down_thresholds(loads, 0.12)
    assert loads.dtype == jnp.float32
    assert ts.dtype == jnp.float32
