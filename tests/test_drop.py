"""Paper §4.1-4.2: 1T/2T token-expert dropping semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import drop, gating, moe, reconstruct


def test_one_t_zero_threshold_keeps_all(rng):
    s = jax.random.uniform(rng, (32, 8), minval=1e-3)
    assert bool(drop.one_t_keep(s, 0.0).all())


def test_one_t_monotone_in_threshold(rng):
    s = jax.random.uniform(rng, (64, 8))
    rates = [float(1 - drop.one_t_keep(s, t).mean())
             for t in (0.0, 0.05, 0.1, 0.2, 0.5)]
    assert rates == sorted(rates)


def test_two_t_equal_thresholds_is_one_t(rng):
    """Paper Table 2 note: T2_major == T2_minor degenerates to 1T-Drop —
    EXACTLY, including at score == T. The scores here contain the
    threshold value itself to pin the boundary: 1T keeps strictly-above
    (``one_t_keep``: score > t), so degenerate 2T must too."""
    t = 0.12
    s = jax.random.uniform(rng, (64, 8))
    s = s.at[0, 0].set(t)                    # exact boundary score
    modes = drop.two_t_modes(s, t, t)
    keep1 = drop.one_t_keep(s, t)
    np.testing.assert_array_equal(np.asarray(modes == drop.MODE_FULL),
                                  np.asarray(keep1))
    # the degenerate band (t, t] is empty: no pair may sit in MAJOR-only
    assert not bool((modes == drop.MODE_MAJOR).any())
    # boundary score drops on both paths
    assert int(modes[0, 0]) == drop.MODE_DROP
    assert not bool(keep1[0, 0])


def test_two_t_boundary_scores(rng):
    """Band boundaries are strict > keeps: score == t_major drops, score ==
    t_minor stays MAJOR-only (consistent with ``threshold_to_drop_rate``
    counting score <= t as dropped)."""
    tm, tn = 0.05, 0.1
    s = jnp.array([[tm, tn, tm - 1e-6, tn + 1e-6]])
    modes = np.asarray(drop.two_t_modes(s, tm, tn))[0]
    np.testing.assert_array_equal(
        modes, [drop.MODE_DROP, drop.MODE_MAJOR, drop.MODE_DROP,
                drop.MODE_FULL])


def test_two_t_degeneracy_property(rng):
    """Property: for random thresholds t, 2T(t, t) keep masks (both halves)
    equal the 1T expansion bit for bit — on scores salted with exact
    threshold values."""
    for seed in range(5):
        k1, k2 = jax.random.split(jax.random.fold_in(rng, seed))
        t = float(jax.random.uniform(k1, ()))
        s = jax.random.uniform(k2, (32, 4))
        s = s.at[0, :2].set(t)               # exact boundary scores
        idx = jnp.tile(jnp.arange(4)[None], (32, 1))
        combine = jnp.full((32, 4), 0.25)
        p2 = drop.expand_pairs_2t(idx, combine, s, 2, t, t)
        p1 = drop.expand_pairs_1t(idx, combine, s, 2, t)
        np.testing.assert_array_equal(np.asarray(p2.keep),
                                      np.asarray(p1.keep))
        np.testing.assert_array_equal(np.asarray(p2.modes),
                                      np.asarray(p1.modes))


def test_two_t_mode_bands(rng):
    s = jnp.array([[0.01, 0.08, 0.2]])
    modes = drop.two_t_modes(s, 0.05, 0.1)
    np.testing.assert_array_equal(np.asarray(modes)[0], [0, 1, 2])


def test_expand_pairs_major_minor_masks():
    idx = jnp.array([[2]])
    combine = jnp.array([[0.6]])
    for score, exp_keep in [(0.2, [True, True]),      # full
                            (0.08, [True, False]),    # major only
                            (0.01, [False, False])]:  # dropped
        pairs = drop.expand_pairs_2t(idx, combine, jnp.array([[score]]),
                                     2, 0.05, 0.1)
        np.testing.assert_array_equal(np.asarray(pairs.keep)[0], exp_keep)
        np.testing.assert_array_equal(np.asarray(pairs.idx)[0], [4, 5])
        np.testing.assert_allclose(np.asarray(pairs.combine)[0], [0.6, 0.6])


def test_drop_rate_and_flops_saved(rng):
    idx = jnp.zeros((100, 1), jnp.int32)
    combine = jnp.ones((100, 1))
    score = jnp.linspace(0, 1, 100)[:, None]
    pairs = drop.expand_pairs_2t(idx, combine, score, 2, 0.25, 0.75)
    # ~25% fully dropped, ~50% major-only, ~25% full
    fs = float(drop.flops_saved_fraction(pairs.modes))
    assert 0.4 < fs < 0.6
    dr = float(drop.drop_rate(pairs))
    assert 0.4 < dr < 0.6


def test_threshold_drop_rate_map_monotone(rng):
    s = jax.random.uniform(rng, (256, 8))
    ts = jnp.linspace(0, 1, 11)
    rates = np.asarray(drop.threshold_to_drop_rate(s, ts))
    assert np.all(np.diff(rates) >= 0)
    assert rates[0] <= 0.01 and rates[-1] >= 0.99


def test_2t_reconstruct_less_error_than_1t(rng, moe_cfg, moe_params,
                                           calib_x):
    """The paper's central accuracy claim (Table 2), as an output-error
    statement: at matched FLOPs savings, 2T with reconstruction approximates
    the full model better than 1T.

    Random-init routers produce nearly-uniform top-k scores, so we sharpen
    the gate (x20) to get a realistic score spread, put T¹ at the median
    normalized score, and choose the 2T band (T¹-g, T¹+g) symmetric around
    it — by construction both policies then save ~the same FLOPs."""
    params = dict(moe_params)
    params["wg"] = moe_params["wg"] * 20.0
    x = calib_x[:64]
    y_full = moe.moe_forward_ref(params, x, moe_cfg)
    r = gating.route(x, params["wg"], moe_cfg.top_k,
                     moe_cfg.router_norm_topk)
    rec = reconstruct.partition_and_reconstruct(params, x, moe_cfg, p=2)

    t1 = float(jnp.quantile(r.norm_score, 0.5))
    gap = float(jnp.quantile(r.norm_score, 0.6)) - t1
    pairs_1t = drop.expand_pairs_1t(r.idx, r.combine, r.norm_score, 2, t1)
    pairs_2t = drop.expand_pairs_2t(r.idx, r.combine, r.norm_score, 2,
                                    t1 - gap, t1 + gap)
    rate1 = float(drop.drop_rate(pairs_1t))
    rate2 = float(drop.drop_rate(pairs_2t))
    assert abs(rate1 - rate2) < 0.1, (rate1, rate2)
    y1 = moe.moe_forward_ref(rec, x, moe_cfg, pairs=pairs_1t)
    y2 = moe.moe_forward_ref(rec, x, moe_cfg, pairs=pairs_2t)
    e1 = float(jnp.mean((y1 - y_full) ** 2))
    e2 = float(jnp.mean((y2 - y_full) ** 2))
    assert e2 <= e1 * 1.05, f"2T ({e2}) should not be worse than 1T ({e1})"


def test_calibration_dtypes_pinned_under_x64(rng):
    """Regression for the f32-explicit calibration math: even under
    jax_enable_x64 (where bool-means and Python-float thresholds would
    silently promote) every calibration output stays float32. The lint's
    calib/threshold entry traces the same guarantee statically."""
    scores = jax.random.uniform(rng, (16, 8), dtype=jnp.float32)
    with jax.experimental.enable_x64():
        t = drop.calibrate_threshold(scores, 0.3)
        rates = drop.threshold_to_drop_rate(scores, [0.05, 0.1, 0.2])
        per_layer = drop.calibrate_per_layer_thresholds([scores, scores],
                                                        0.25)
    assert t.dtype == jnp.float32
    assert rates.dtype == jnp.float32
    assert per_layer.dtype == jnp.float32
    assert per_layer.shape == (2, 2)
