"""End-to-end behaviour of the DualSparse-MoE system (paper pipeline):
pre-trained model -> profile -> reconstruct -> partial transform -> 2T-Drop
serving, plus training convergence and the serving engine."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import pipeline
from repro.models import model as M
from repro.optim import adamw, cosine_schedule
from repro.serving import GenerationConfig, ServingEngine


def test_training_loss_decreases(rng):
    cfg = get_config("olmoe-lite")
    params = M.init_params(rng, cfg)
    opt = adamw(cosine_schedule(3e-3, 40, warmup=4))
    ost = opt.init(params)
    step = jax.jit(M.make_train_step(cfg, opt))
    loader = pipeline.make_loader(cfg, 8, 32)
    losses = []
    for i in range(25):
        params, ost, loss = step(params, ost, loader.get_batch(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_dualsparse_end_to_end(rng):
    """Full §4.2 pipeline on a model: transformed params + 2T thresholds
    produce outputs close to the untransformed model while actually dropping
    computation."""
    cfg = get_config("olmoe-lite")
    params = M.init_params(rng, cfg)
    calib = pipeline.calibration_activations(jax.random.fold_in(rng, 3),
                                             256, cfg.d_model)
    tparams = M.transform_params_for_dualsparse(params, cfg, calib)
    # shapes: experts doubled, width halved
    assert tparams["blocks"]["moe"]["w1"].shape == (
        cfg.n_layers, cfg.n_experts * 2, cfg.d_model, cfg.d_expert // 2)

    from repro.core.policy import make_policy
    from repro.models.transformer import DistContext
    from repro.launch.mesh import make_host_mesh
    dist = DistContext(mesh=make_host_mesh(1), moe_impl="dispatch",
                       policy=make_policy("2t", cfg.dualsparse))
    batch = M.make_batch(rng, cfg, 2, 32, "train")
    base = M.loss_fn(params, batch, cfg)
    dropped = M.loss_fn(tparams, batch, cfg, dist=dist)
    assert jnp.isfinite(dropped)
    # the drop perturbs the loss only mildly
    assert abs(float(dropped) - float(base)) < 0.35 * float(base)


def test_drop_rate_tracks_flops_on_model(rng):
    """Threshold ordering: a higher threshold band drops strictly more."""
    cfg = get_config("olmoe-lite")
    params = M.init_params(rng, cfg)
    x = pipeline.calibration_activations(rng, 512, cfg.d_model)
    from repro.core import moe as moe_mod, reconstruct
    from repro.core.drop import drop_rate
    moe_p = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
    rec = reconstruct.partition_and_reconstruct(moe_p, x, cfg, p=2)
    rec["wg"] = moe_p["wg"]
    lo = moe_mod.route_dualsparse(rec, x, cfg, thresholds=(0.02, 0.04))
    hi = moe_mod.route_dualsparse(rec, x, cfg, thresholds=(0.12, 0.14))
    assert float(drop_rate(hi)) > float(drop_rate(lo))


def test_serving_engine_batches(rng):
    cfg = get_config("mixtral-8x7b-lite")
    params = M.init_params(rng, cfg)
    eng = ServingEngine(cfg, params, batch_size=4, max_prompt_len=16,
                        max_new_tokens=8)
    prompts = [np.arange(10) % cfg.vocab_size,
               (np.arange(16) * 3) % cfg.vocab_size,
               np.arange(16) % cfg.vocab_size]
    res = eng.generate(prompts, GenerationConfig(max_new_tokens=8))
    assert len(res) == 3
    assert all(len(r.tokens) == 8 for r in res)
    # greedy decoding is deterministic
    eng2 = ServingEngine(cfg, params, batch_size=4, max_prompt_len=16,
                         max_new_tokens=8)
    res2 = eng2.generate(prompts, GenerationConfig(max_new_tokens=8))
    assert [r.tokens for r in res] == [r.tokens for r in res2]


def test_serving_engine_equal_prompts_match_prefill_oracle(rng):
    """With equal-length prompts the engine must reproduce exactly the
    prefill+greedy-decode of the underlying model."""
    cfg = get_config("mixtral-8x7b-lite")
    params = M.init_params(rng, cfg)
    L = 12
    prompts = [np.asarray((np.arange(L) * 7) % cfg.vocab_size),
               np.asarray((np.arange(L) * 11) % cfg.vocab_size)]
    eng = ServingEngine(cfg, params, batch_size=2, max_prompt_len=L,
                        max_new_tokens=4)
    res = eng.generate(prompts, GenerationConfig(max_new_tokens=4))

    batch = {"tokens": jnp.asarray(np.stack(prompts))}
    prefill = jax.jit(M.make_prefill_step(
        cfg, cache_len=M.context_len_for(cfg, L, 4)))
    logits, cache = prefill(params, batch)
    serve = jax.jit(M.make_serve_step(cfg))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    expect = [[], []]
    for _ in range(4):
        for b in range(2):
            expect[b].append(int(tok[b, 0]))
        logits, cache = serve(params, tok, cache)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    assert [r.tokens for r in res] == expect


def test_moe_aux_loss_training(rng):
    """Switch-style load-balance aux loss: enabled training balances expert
    loads measurably better than plain CE training."""
    cfg = get_config("olmoe-lite")
    from repro.core import gating

    def imbalance_after(aux_coef, steps=15):
        params = M.init_params(rng, cfg)
        opt = adamw(3e-3)
        ost = opt.init(params)
        step = jax.jit(M.make_train_step(cfg, opt, aux_coef=aux_coef))
        loader = pipeline.make_loader(cfg, 8, 32)
        for i in range(steps):
            params, ost, _ = step(params, ost, loader.get_batch(i))
        x = pipeline.calibration_activations(rng, 1024, cfg.d_model)
        moe_p = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
        r = gating.route(x, moe_p["wg"], cfg.top_k, cfg.router_norm_topk)
        hist = gating.expert_histogram(r.idx, cfg.n_experts)
        h = hist.astype(jnp.float32)
        return float(h.max() / jnp.maximum(h.mean(), 1e-9))

    # both finite and training runs; aux keeps max/mean load ratio bounded
    imb_aux = imbalance_after(0.05)
    assert imb_aux < 12.0
