"""Distributed behaviour (shard_map S-ETP/ETP, load-aware EP, dry-run) via
subprocesses that set --xla_force_host_platform_device_count=8 BEFORE jax
imports. The main pytest process keeps its single real device."""
import json
import os
import subprocess
import sys


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PROGS = os.path.join(ROOT, "tests", "dist_progs")


def run_prog(name, *args, devices=8, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run([sys.executable, os.path.join(PROGS, name), *args],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert p.returncode == 0, f"{name} failed:\n{p.stdout}\n{p.stderr}"
    return p.stdout


def test_setp_exactness():
    out = run_prog("setp_check.py")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["plain_err"] < 1e-5
    assert res["dualsparse_keepall_err"] < 1e-5
    assert res["etp_err"] < 1e-5
    assert res["load_aware_finite"]


def test_setp_uses_only_all_to_all():
    """Paper §3.3: S-ETP's MoE communication is AlltoAll only, while ETP
    additionally pays AllGather + ReduceScatter."""
    out = run_prog("collective_pattern.py")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["setp"].get("all-to-all", 0) > 0
    assert res["setp"].get("all-gather", 0) == 0
    assert res["setp"].get("reduce-scatter", 0) == 0
    assert res["etp"].get("all-gather", 0) > 0
    assert res["etp"].get("reduce-scatter", 0) > 0
    assert res["setp_bytes"] < res["etp_bytes"]


def test_dryrun_micro():
    """dryrun machinery end-to-end on an 8-device mesh (fast micro check
    that lowering+compile+analysis all work in one process)."""
    out = run_prog("dryrun_micro.py")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["status"] == "ok"
    assert res["flops"] > 0
    assert res["collective_bytes"] > 0


def test_distributed_train_step_runs():
    out = run_prog("train_dist_check.py")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["loss_finite"]
    assert res["loss1"] < res["loss0"] * 1.2  # it trains (or at least moves)


def test_decode_loads_not_double_counted():
    """Regression: on a decode step (S==1) the token block is replicated
    over the expert axis, and the loads psum must NOT sum the n_dev
    identical copies — each token counts once, matching the prefill path."""
    out = run_prog("loads_decode_check.py")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["decode_loads_once"], res
    assert res["prefill_loads_once"], res
    assert res["decode_matches_prefill"], res
    assert res["finite"], res


def test_distributed_dualsparse_serving():
    """Engine + S-ETP + 2T-Drop + load-aware thresholding on 8 devices."""
    out = run_prog("serve_dist_check.py")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["ok"], res
