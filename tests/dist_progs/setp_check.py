"""S-ETP / ETP exactness on an 8-device host mesh (run via subprocess)."""
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import moe, setp, reconstruct
from repro.models.layers import split_params
from repro.launch.mesh import make_mesh_auto, use_mesh


def main():
    cfg = get_config("olmoe-lite")
    key = jax.random.PRNGKey(0)
    params, _ = split_params(moe.make_moe_params(key, cfg))
    mesh = make_mesh_auto((2, 4), ("data", "model"))
    B, S, d = 4, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.5
    y_ref = moe.moe_forward_ref(params, x.reshape(-1, d), cfg).reshape(B, S, d)

    pl = setp.place_params_strided(params, 4)
    with use_mesh(mesh):
        y = setp.setp_moe_forward(pl, x, cfg, mesh, cap_factor=4.0,
                                  local_cap_factor=8.0,
                                  wire_dtype=jnp.float32)
    plain_err = float(jnp.abs(y - y_ref).max())

    from repro.core.policy import LoadAwareTwoT, TwoTDrop
    pr = reconstruct.partition_and_reconstruct(params, x.reshape(-1, d), cfg,
                                               p=2)
    pr = setp.place_params_strided(pr, 4)
    keep_all = TwoTDrop(partition_p=2, t_major=-1.0, t_minor=-1.0)
    with use_mesh(mesh):
        y2 = setp.setp_moe_forward(pr, x, cfg, mesh, policy=keep_all,
                                   cap_factor=4.0, local_cap_factor=8.0,
                                   wire_dtype=jnp.float32)
    ds_err = float(jnp.abs(y2 - y_ref).max())

    la = LoadAwareTwoT(partition_p=2, t_max=cfg.dualsparse.t_max)
    with use_mesh(mesh):
        y3 = setp.setp_moe_forward(pr, x, cfg, mesh, policy=la,
                                   cap_factor=4.0, local_cap_factor=8.0,
                                   wire_dtype=jnp.float32)
    la_finite = bool(jnp.isfinite(y3).all())

    mesh2 = make_mesh_auto((4, 2), ("ep", "tp"))
    with use_mesh(mesh2):
        y4 = setp.etp_moe_forward(params, x, cfg, mesh2, cap_factor=4.0,
                                  local_cap_factor=8.0)
    etp_err = float(jnp.abs(y4 - y_ref).max())

    print(json.dumps({"plain_err": plain_err,
                      "dualsparse_keepall_err": ds_err,
                      "load_aware_finite": la_finite,
                      "etp_err": etp_err}))


if __name__ == "__main__":
    main()
