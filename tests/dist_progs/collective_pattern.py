"""Fig 5 / Fig 9 structural check: S-ETP lowers to AlltoAll only; ETP lowers
to AlltoAll + AllGather + ReduceScatter, and moves more bytes."""
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import moe, setp
from repro.launch.hlo_analysis import analyze_hlo
from repro.models.layers import split_params
from repro.launch.mesh import make_mesh_auto, use_mesh


def main():
    cfg = get_config("olmoe-lite")
    key = jax.random.PRNGKey(0)
    params, _ = split_params(moe.make_moe_params(key, cfg))
    B, S, d = 8, 32, cfg.d_model
    x = jax.ShapeDtypeStruct((B, S, d), jnp.float32)

    mesh = make_mesh_auto((2, 4), ("data", "model"))
    pl = setp.place_params_strided(params, 4)
    with use_mesh(mesh):
        comp = jax.jit(lambda p, xx: setp.setp_moe_forward(
            p, xx, cfg, mesh, cap_factor=2.0)).lower(pl, x).compile()
    c1 = analyze_hlo(comp.as_text())

    mesh2 = make_mesh_auto((4, 2), ("ep", "tp"))
    with use_mesh(mesh2):
        comp2 = jax.jit(lambda p, xx: setp.etp_moe_forward(
            p, xx, cfg, mesh2, cap_factor=2.0)).lower(params, x).compile()
    c2 = analyze_hlo(comp2.as_text())

    print(json.dumps({
        "setp": c1.bytes_by_kind, "etp": c2.bytes_by_kind,
        "setp_bytes": c1.collective_bytes, "etp_bytes": c2.collective_bytes,
    }))


if __name__ == "__main__":
    main()
