"""Load-aware histogram on decode steps (run via subprocess, 8 devices).

Regression for the `_setp_body` double-count: on a decode step (S == 1) the
token block is REPLICATED over the expert axis, and the old psum over
``token_axes + (axis,)`` summed n_dev identical per-device histograms —
multiplying every load by n_dev. The body must count each token exactly
once on BOTH paths; we capture the psum'd ``loads`` the policy actually
receives (via a recording ``sub_pair_keep``) and compare decode vs prefill
vs the single-process ground-truth histogram.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import dispatch, gating, moe, reconstruct, setp
from repro.core.policy import LoadAwareTwoT
from repro.launch.mesh import make_mesh_auto, use_mesh
from repro.models.layers import split_params

RECORDED = []


def main():
    cfg = get_config("olmoe-lite")
    key = jax.random.PRNGKey(0)
    params, _ = split_params(moe.make_moe_params(key, cfg))
    params["wg"] = params["wg"] * 20.0          # spread the gating scores
    mesh = make_mesh_auto((2, 4), ("data", "model"))
    n_dev, d = 4, cfg.d_model
    toks = jax.random.normal(jax.random.PRNGKey(1), (8, d)) * 0.5

    pr = reconstruct.partition_and_reconstruct(params, toks, cfg, p=2)
    pr = setp.place_params_strided(pr, n_dev)

    # ground truth: every token counted ONCE, strided sub-expert placement
    r = gating.route(toks, params["wg"], cfg.top_k, cfg.router_norm_topk)
    sub = jnp.arange(2, dtype=r.idx.dtype)
    sub_idx = (r.idx[:, :, None] * 2 + sub).reshape(8, -1)
    expected = np.asarray(dispatch.group_histogram(sub_idx % n_dev, n_dev,
                                                   dtype=jnp.float32))

    orig = LoadAwareTwoT.sub_pair_keep

    def recording(self, score, is_major, sub_idx, cfg, *, n_dev=1,
                  loads=None, thresholds=None):
        def cb(l):
            RECORDED.append(np.asarray(l))
        jax.debug.callback(cb, loads)
        return orig(self, score, is_major, sub_idx, cfg, n_dev=n_dev,
                    loads=loads, thresholds=thresholds)

    LoadAwareTwoT.sub_pair_keep = recording
    la = LoadAwareTwoT(partition_p=2, t_max=cfg.dualsparse.t_max)

    def run(x):
        RECORDED.clear()
        with use_mesh(mesh):
            y = setp.setp_moe_forward(pr, x, cfg, mesh, policy=la,
                                      cap_factor=4.0, local_cap_factor=8.0,
                                      wire_dtype=jnp.float32)
        jax.effects_barrier()
        return np.asarray(y), [l.copy() for l in RECORDED]

    # decode: (B=8, S=1) — seq not divisible by n_dev => tokens REPLICATED
    # over the expert axis (the buggy case)
    y_dec, dec = run(toks.reshape(8, 1, d))
    # prefill: (B=2, S=4) — seq sharded over the expert axis
    y_pre, pre = run(toks.reshape(2, 4, d))

    dec_ok = bool(dec) and all(np.array_equal(l, expected) for l in dec)
    pre_ok = bool(pre) and all(np.array_equal(l, expected) for l in pre)
    print(json.dumps({
        "decode_loads_once": dec_ok,
        "prefill_loads_once": pre_ok,
        "decode_matches_prefill": bool(
            dec and pre and np.array_equal(dec[0], pre[0])),
        "n_records": [len(dec), len(pre)],
        "expected": expected.tolist(),
        "decode_first": dec[0].tolist() if dec else None,
        "finite": bool(np.isfinite(y_dec).all() and np.isfinite(y_pre).all()),
    }))


if __name__ == "__main__":
    main()
