"""Distributed serving: the full DualSparse inference system (partition +
reconstruction + 2T-Drop + load-aware thresholds) through the S-ETP
shard_map path on an 8-device mesh, end to end via the serving engine."""
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM, calibration_activations
from repro.models import model as M
from repro.models.transformer import DistContext
from repro.serving import GenerationConfig, ServingEngine
from repro.launch.mesh import make_mesh_auto, use_mesh


def main():
    cfg = get_config("olmoe-lite")
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    mesh = make_mesh_auto((2, 4), ("data", "model"))
    calib = calibration_activations(jax.random.fold_in(key, 7), 256,
                                    cfg.d_model)
    from repro.core.policy import make_policy
    pol = make_policy("load_aware", cfg.dualsparse)
    tparams, pol = pol.prepare(params, cfg, calib, n_ep_devices=4)
    dist = DistContext(mesh=mesh, moe_impl="setp", policy=pol)
    src = SyntheticLM(cfg.vocab_size)
    prompts = [np.asarray(src.sample_batch(jax.random.fold_in(key, i), 1,
                                           12)["tokens"][0])
               for i in range(2)]
    with use_mesh(mesh):
        eng = ServingEngine(cfg, tparams, batch_size=2, max_prompt_len=12,
                            max_new_tokens=4, dist=dist)
        res = eng.generate(prompts, GenerationConfig(max_new_tokens=4))
    ok = (len(res) == 2 and all(len(r.tokens) == 4 for r in res)
          and all(0 <= t < cfg.vocab_size for r in res for t in r.tokens))
    print(json.dumps({"ok": bool(ok),
                      "tokens": [r.tokens for r in res]}))


if __name__ == "__main__":
    main()
