"""Micro dry-run: the launch machinery (specs + lower + compile + HLO
analysis) on an 8-device mesh with a reduced arch — fast integration check
of repro.launch without the 512-device production mesh."""
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import specs
from repro.launch.hlo_analysis import analyze_hlo
from repro.models import model as M
from repro.models.transformer import DistContext
from repro.optim import adamw
from repro.optim.adamw import AdamWState
from repro.launch.mesh import make_mesh_auto, use_mesh


def main():
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    mesh = make_mesh_auto((2, 4), ("data", "model"))
    params, axes = M.abstract_params_and_axes(cfg, jnp.float32)
    psh = specs.param_shardings(cfg, params, axes, mesh)
    opt = adamw(1e-4)
    ost = jax.eval_shape(opt.init, params)
    osh = AdamWState(step=jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec()), mu=psh, nu=psh)
    batch = specs.abstract_batch(cfg, 8, 64, "train")
    bsh = specs.batch_shardings(cfg, batch, mesh)
    dist = DistContext(mesh=mesh, moe_impl="setp")
    step = M.make_train_step(cfg, opt, dist=dist)
    with use_mesh(mesh):
        comp = jax.jit(step, in_shardings=(psh, osh, bsh)).lower(
            params, ost, batch).compile()
    c = analyze_hlo(comp.as_text())
    print(json.dumps({"status": "ok", "flops": c.flops,
                      "collective_bytes": c.collective_bytes,
                      "by_kind": c.bytes_by_kind}))


if __name__ == "__main__":
    main()
