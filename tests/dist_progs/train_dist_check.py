"""Actually EXECUTE a distributed train step (8 host devices): S-ETP MoE,
sharded params, two steps, loss finite and moving."""
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import pipeline
from repro.launch import specs
from repro.models import model as M
from repro.models.transformer import DistContext
from repro.optim import adamw
from repro.launch.mesh import make_mesh_auto, use_mesh


def main():
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    mesh = make_mesh_auto((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    params, axes = M.init_params_and_axes(key, cfg)
    psh = specs.param_shardings(cfg, params, axes, mesh)
    params = jax.device_put(params, psh)
    opt = adamw(3e-3)
    ost = opt.init(params)
    dist = DistContext(mesh=mesh, moe_impl="setp")
    step = jax.jit(M.make_train_step(cfg, opt, dist=dist))
    loader = pipeline.make_loader(cfg, 8, 32)
    losses = []
    with use_mesh(mesh):
        for i in range(6):
            params, ost, loss = step(params, ost, loader.get_batch(i))
            losses.append(float(loss))
    print(json.dumps({"loss_finite": all(jnp.isfinite(jnp.array(losses))),
                      "loss0": losses[0], "loss1": losses[-1]}))


if __name__ == "__main__":
    main()
