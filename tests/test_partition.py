"""Paper §3: expert partition preserves the MoE function exactly
(Eq. 11 complete, Eq. 13 partial)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import drop, gating, moe, partition


def _ref(params, x, cfg, **kw):
    return moe.moe_forward_ref(params, x, cfg, **kw)


@pytest.mark.parametrize("p", [2, 4, 8])
def test_complete_transform_exact(rng, moe_cfg, moe_params, p):
    x = jax.random.normal(jax.random.fold_in(rng, p), (48, moe_cfg.d_model))
    y0 = _ref(moe_params, x, moe_cfg)
    pc = partition.complete_transform(moe_params, p)
    cfg_p = dataclasses.replace(moe_cfg, n_experts=moe_cfg.n_experts * p,
                                top_k=moe_cfg.top_k * p,
                                d_expert=moe_cfg.d_expert // p)
    yc = _ref(pc, x, cfg_p)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yc), atol=1e-5)


def test_complete_transform_gating_scores(rng, moe_cfg, moe_params):
    """Eq. 9: each partitioned copy carries exactly 1/P of the original
    softmax score, and copies of one expert tie."""
    p = 4
    x = jax.random.normal(rng, (8, moe_cfg.d_model))
    s0 = jax.nn.softmax(gating.gate_logits(x, moe_params["wg"]), -1)
    pc = partition.complete_transform(moe_params, p)
    sp = jax.nn.softmax(gating.gate_logits(x, pc["wg"]), -1)
    got = np.asarray(sp.reshape(8, -1, p))
    want = np.broadcast_to(np.asarray(s0[..., None] / p), got.shape)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-9)


@pytest.mark.parametrize("p", [2, 4])
def test_partial_transform_exact(rng, moe_cfg, moe_params, p):
    x = jax.random.normal(jax.random.fold_in(rng, 10 + p),
                          (48, moe_cfg.d_model))
    y0 = _ref(moe_params, x, moe_cfg)
    pp = partition.partial_transform(moe_params, p)
    r = gating.route(x, moe_params["wg"], moe_cfg.top_k,
                     moe_cfg.router_norm_topk)
    pairs = drop.expand_pairs_2t(r.idx, r.combine, r.norm_score, p,
                                 -1.0, -1.0)   # keep everything
    yp = _ref(pp, x, moe_cfg, pairs=pairs)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yp), atol=1e-5)


def test_partial_transform_index_remap(rng, moe_cfg):
    """Eq. 12: sub-expert ids are i*P + p, contiguous per original expert."""
    idx = jnp.array([[3, 7]])
    combine = jnp.ones((1, 2))
    score = jnp.full((1, 2), 0.5)
    pairs = drop.expand_pairs_2t(idx, combine, score, 2, -1.0, -1.0)
    assert sorted(np.asarray(pairs.idx[0]).tolist()) == [6, 7, 14, 15]


def test_partial_roundtrip(moe_params):
    pp = partition.partial_transform(moe_params, 4)
    back = partition.invert_partial(pp, 4)
    for k in ("w1", "w3", "w2"):
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(moe_params[k]))


def test_dense_ffn_partition_exact(rng):
    d, f, p = 32, 64, 4
    ks = jax.random.split(rng, 4)
    w1 = jax.random.normal(ks[0], (d, f))
    w3 = jax.random.normal(ks[1], (d, f))
    w2 = jax.random.normal(ks[2], (f, d))
    x = jax.random.normal(ks[3], (16, d))
    from repro.models.layers import swiglu
    y0 = swiglu(x, w1, w3, w2)
    w1p, w3p, w2p = partition.dense_ffn_partition(w1, w3, w2, p)
    y = sum(swiglu(x, w1p[i], w3p[i], w2p[i]) for i in range(p))
    # unit-scale weights -> outputs O(100); f32 summation-order noise ~1e-4
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y), rtol=2e-5,
                               atol=1e-3)


def test_w2_scaling_factor(moe_params):
    """Complete transformation scales W2 by exactly P (paper's choice (2))."""
    p = 2
    pc = partition.complete_transform(moe_params, p)
    pp = partition.partial_transform(moe_params, p)
    np.testing.assert_allclose(np.asarray(pc["w2"]), np.asarray(pp["w2"]) * p,
                               rtol=1e-6)
