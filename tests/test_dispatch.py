"""Sort-based mode-ordered dispatch (core.dispatch): equivalence with the
one-hot-cumsum oracle, bit-exact moe_forward_dispatch behaviour, and the
counts_major wiring into the dual-sparse kernel on the dispatch and S-ETP
production paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given

    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=20,
        suppress_health_check=list(hypothesis.HealthCheck))
    hypothesis.settings.load_profile("ci")
except ImportError:
    from _hypothesis_compat import st, given, settings  # noqa: F401

from repro.core import dispatch as D
from repro.core import drop, gating, moe, setp
from repro.core.policy import TwoTDrop
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# Property: sort_dispatch == cumsum_dispatch (plans, buffers, overflow)
# ---------------------------------------------------------------------------

@st.composite
def dispatch_cases(draw):
    n = draw(st.sampled_from([1, 7, 64, 300, 1024]))
    g = draw(st.sampled_from([1, 3, 8, 32]))
    cap = draw(st.sampled_from([1, 4, 16, 64]))
    keep_p = draw(st.floats(0.0, 1.0))
    major_p = draw(st.floats(0.0, 1.0))
    with_modes = draw(st.booleans())
    seed = draw(st.integers(0, 2 ** 16))
    return n, g, cap, keep_p, major_p, with_modes, seed


@given(dispatch_cases())
def test_sort_matches_cumsum_oracle(case):
    n, g, cap, keep_p, major_p, with_modes, seed = case
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    group = jax.random.randint(ks[0], (n,), 0, g)
    keep = jax.random.bernoulli(ks[1], keep_p, (n,))
    major = (jax.random.bernoulli(ks[2], major_p, (n,)) & keep) \
        if with_modes else None
    a = D.sort_dispatch(group, keep, n_groups=g, capacity=cap,
                        major_only=major)
    b = D.cumsum_dispatch(group, keep, n_groups=g, capacity=cap,
                          major_only=major)
    for name in ("perm", "group_offsets", "counts_full", "counts_major",
                 "group", "slot", "overflow"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"{name} diverges (case {case})")
    # buffer construction: gather (new) == repeat+scatter (old), bit for bit
    x = jax.random.normal(ks[3], (n, 5))
    np.testing.assert_array_equal(
        np.asarray(D.gather_rows(x, a, cap)),
        np.asarray(D.scatter_rows(x, b, cap)))
    # overflow is exactly the kept pairs beyond per-group capacity
    hist = np.bincount(np.asarray(group)[np.asarray(keep)], minlength=g)
    assert int(a.overflow) == int(np.maximum(hist - cap, 0).sum())


def test_mode_ordering_full_rows_first():
    """MAJOR-only pairs seat after every FULL pair of their group, each in
    arrival order — the row layout the dual-sparse kernel requires."""
    group = jnp.asarray([0, 0, 0, 0, 1, 0])
    keep = jnp.asarray([True, True, True, True, True, False])
    major = jnp.asarray([True, False, True, False, False, False])
    plan = D.sort_dispatch(group, keep, n_groups=2, capacity=8,
                           major_only=major)
    # group 0 buffer: FULL pairs 1,3 then MAJOR-only pairs 0,2
    np.testing.assert_array_equal(np.asarray(plan.perm[:4]), [1, 3, 0, 2])
    np.testing.assert_array_equal(np.asarray(plan.counts_full), [2, 1])
    np.testing.assert_array_equal(np.asarray(plan.counts_major), [2, 0])
    np.testing.assert_array_equal(np.asarray(plan.slot), [2, 0, 3, 1, 0, 8])


# ---------------------------------------------------------------------------
# moe_forward_dispatch is bit-exact vs the pre-sort scatter implementation
# ---------------------------------------------------------------------------

def _old_scatter_dispatch(params, x, cfg, pairs, capacity):
    """The pre-sort moe_forward_dispatch math (one-hot cumsum slotting,
    jnp.repeat + scatter buffers), kept as the bit-exactness oracle."""
    T, d = x.shape
    E = params["w1"].shape[0]
    K = pairs.idx.shape[1]
    plan = D.cumsum_dispatch(pairs.idx, pairs.keep, n_groups=E,
                             capacity=capacity)
    buf = D.scatter_rows(x, plan, capacity, index_div=K)
    out_buf = moe.expert_ffn(params["w1"], params["w3"], params["w2"], buf)
    gathered = D.unpermute(out_buf, plan)
    w = (pairs.combine * pairs.keep.astype(pairs.combine.dtype)).reshape(-1)
    y = (gathered * w[:, None].astype(gathered.dtype))
    y = y.reshape(T, K, d).sum(axis=1).astype(x.dtype)
    return y + moe._shared_out(params, x), plan.overflow


@pytest.mark.parametrize("capacity", [4, 64])
def test_dispatch_bit_exact_vs_cumsum_path(rng, moe_cfg, moe_params,
                                           capacity):
    """At EQUAL capacity the sort-based forward must reproduce the old
    cumsum/scatter forward bit for bit — same seats, same drops, same sums
    — including under capacity overflow."""
    x = jax.random.normal(rng, (64, moe_cfg.d_model)) * 0.5
    pairs = moe.route_plain(moe_params, x, moe_cfg)
    y_new, of_new = moe.moe_forward_dispatch(
        moe_params, x, moe_cfg, pairs=pairs, capacity=capacity,
        return_overflow=True)
    y_old, of_old = _old_scatter_dispatch(moe_params, x, moe_cfg, pairs,
                                          capacity)
    np.testing.assert_array_equal(np.asarray(y_new), np.asarray(y_old))
    assert int(of_new) == int(of_old)


# ---------------------------------------------------------------------------
# counts_major reaches the kernel in production (dispatch path)
# ---------------------------------------------------------------------------

def _spying_grouped_swiglu(record):
    orig = kops.grouped_swiglu

    def spy(x, w1, w3, w2, counts_full=None, counts_major=None, **kw):
        def cb(cf, cm):
            record.append((np.asarray(cf), np.asarray(cm)))
        if counts_major is not None:
            jax.debug.callback(cb, counts_full, counts_major)
        return orig(x, w1, w3, w2, counts_full, counts_major, **kw)
    return spy


def _two_t_setup(rng, moe_cfg, moe_params, calib_x):
    """Prepared 2T params + thresholds that actually produce mode-1 pairs
    (router sharpened so normalized scores spread)."""
    from benchmarks.common import sharp_router_params
    params = sharp_router_params(moe_params)
    pol = TwoTDrop(partition_p=2, use_kernel=True)
    prepared, _ = pol.prepare(params, moe_cfg, calib_x)
    r = gating.route(calib_x, params["wg"], moe_cfg.top_k,
                     moe_cfg.router_norm_topk)
    t1 = float(jnp.quantile(r.norm_score, 0.35))
    pol = dataclasses.replace(pol, t_major=t1 - 0.02, t_minor=t1 + 0.02)
    pairs = pol.route(prepared, calib_x, moe_cfg)
    modes = np.asarray(pairs.modes)
    assert (modes == drop.MODE_MAJOR).sum() > 0, \
        "setup must yield MAJOR-only pairs"
    return prepared, pol, pairs


def test_counts_major_reaches_kernel_dispatch_path(rng, moe_cfg, moe_params,
                                                   calib_x, monkeypatch):
    """A 2t policy with use_kernel=True on the dispatch path must hand the
    kernel mode-ordered ORIGINAL-expert buffers with nonzero counts_major,
    skip >0 minor-half tiles, and stay exact vs the dense reference."""
    prepared, pol, pairs = _two_t_setup(rng, moe_cfg, moe_params, calib_x)
    record = []
    monkeypatch.setattr(kops, "grouped_swiglu", _spying_grouped_swiglu(record))
    T = calib_x.shape[0]
    # fused_pipeline=False pins the buffer-kernel path this test spies on
    # (auto would pick the fused pipeline here, which never calls
    # grouped_swiglu)
    y, overflow = moe.moe_forward_dispatch(
        prepared, calib_x, moe_cfg, pairs=pairs, capacity=T,
        use_kernel=True, return_overflow=True,
        mode_grouped=pol.kernel_mode_grouping, fused_pipeline=False)
    y_ref = moe.moe_forward_ref(prepared, calib_x, moe_cfg, pairs=pairs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    assert int(overflow) == 0
    assert record, "kernel was never invoked with counts_major"
    cf, cm = record[-1]
    assert cm.sum() > 0, "no MAJOR-only rows reached the kernel"
    # the paper's §4.2 cash-in: whole minor-half MXU tiles never issued
    from benchmarks.bench_kernel_skip import tile_skip_fraction
    f_full = prepared["w1"].shape[-1] * 2
    skip = tile_skip_fraction(cf, cm, T, f_full, block_c=32, block_f=32)
    assert skip > 0.0


def test_fused_kernel_halves_dispatched_pairs(rng, moe_cfg, moe_params,
                                              calib_x):
    """Mode grouping dispatches one row per ORIGINAL pair: the fused plan
    seats at most half the rows of the sub-expert plan at P=2."""
    prepared, pol, pairs = _two_t_setup(rng, moe_cfg, moe_params, calib_x)
    E_sub = prepared["w1"].shape[0]
    sub_plan = D.sort_dispatch(pairs.idx, pairs.keep, n_groups=E_sub,
                               capacity=calib_x.shape[0])
    fused = D.fuse_sub_pairs(pairs, 2)
    fused_plan = D.sort_dispatch(fused.group, fused.keep,
                                 n_groups=E_sub // 2,
                                 capacity=calib_x.shape[0],
                                 major_only=fused.major_only)
    assert int(fused_plan.counts.sum()) < int(sub_plan.counts.sum())


# ---------------------------------------------------------------------------
# counts_major reaches the kernel on the S-ETP path + overflow accounting
# ---------------------------------------------------------------------------

def _one_dev_mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(1)


def test_counts_major_reaches_kernel_setp_path(rng, moe_cfg, moe_params,
                                               calib_x, monkeypatch):
    """The S-ETP shard_map body must order each local sub-expert's buffer
    FULL-first/MAJOR-only-second and pass counts_major to the kernel, while
    matching the dense reference."""
    prepared, pol, pairs = _two_t_setup(rng, moe_cfg, moe_params, calib_x)
    # fused_pipeline=False pins the buffer-kernel path this test spies on
    # (auto would pick the fused pipeline here, which never calls
    # grouped_swiglu)
    pol = dataclasses.replace(pol, fused_pipeline=False)
    record = []
    monkeypatch.setattr(kops, "grouped_swiglu", _spying_grouped_swiglu(record))
    mesh = _one_dev_mesh()
    placed = setp.place_params_strided(prepared, 1)
    x3 = calib_x[:64].reshape(1, 64, -1)
    y, overflow = setp.setp_moe_forward(
        placed, x3, moe_cfg, mesh, policy=pol, cap_factor=4.0,
        local_cap_factor=4.0, wire_dtype=jnp.float32, return_overflow=True)
    pairs64 = pol.route(prepared, calib_x[:64], moe_cfg)
    y_ref = moe.moe_forward_ref(prepared, calib_x[:64], moe_cfg,
                                pairs=pairs64)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(y_ref),
                               atol=2e-4, rtol=1e-4)
    assert int(overflow) == 0
    assert record, "kernel was never invoked with counts_major on S-ETP"
    cf, cm = record[-1]
    assert cm.sum() > 0, "no MAJOR-only rows reached the S-ETP kernel"


def test_setp_overflow_counter_surfaces(rng, moe_cfg, moe_params, calib_x):
    """Starving the S-ETP capacities must report overflow > 0 (previously
    invisible on this path); ample capacity reports exactly 0."""
    pol = TwoTDrop(partition_p=2, t_major=-1.0, t_minor=-1.0)
    prepared, pol = pol.prepare(moe_params, moe_cfg, calib_x)
    placed = setp.place_params_strided(prepared, 1)
    mesh = _one_dev_mesh()
    x3 = calib_x[:64].reshape(1, 64, -1)
    _, of0 = setp.setp_moe_forward(placed, x3, moe_cfg, mesh, policy=pol,
                                   cap_factor=4.0, local_cap_factor=4.0,
                                   return_overflow=True)
    assert int(of0) == 0
    y, of1 = setp.setp_moe_forward(placed, x3, moe_cfg, mesh, policy=pol,
                                   cap_factor=4.0, local_cap_factor=0.05,
                                   cap_multiple=1, return_overflow=True)
    assert int(of1) > 0
    assert bool(jnp.isfinite(y).all())


# ---------------------------------------------------------------------------
# Fused sub-expert kernel mode vs merged-weight oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,C,d,f,P,bc,bf", [
    (2, 32, 32, 64, 2, 16, 16),
    (3, 17, 16, 48, 2, 8, 8),        # C not block-aligned
    (2, 16, 16, 64, 4, 8, 8),        # P = 4
    (1, 8, 8, 24, 2, 8, 8),          # sub width not block-aligned (padding)
])
def test_kernel_p_factor_matches_merged_weights(rng, E, C, d, f, P, bc, bf):
    """p_factor indexing must equal physically re-merging the partitioned
    weights into full-width experts."""
    from repro.core import partition
    from repro.kernels import ref as kref
    ks = jax.random.split(rng, 6)
    x = jax.random.normal(ks[0], (E, C, d)) * 0.5
    w1 = jax.random.normal(ks[1], (E, d, f)) * 0.1
    w3 = jax.random.normal(ks[2], (E, d, f)) * 0.1
    w2 = jax.random.normal(ks[3], (E, f, d)) * 0.1
    cf = jax.random.randint(ks[4], (E,), 0, C // 2 + 1)
    cm = jax.random.randint(ks[5], (E,), 0, C // 2 + 1)
    sub = partition.partial_transform({"w1": w1, "w3": w3, "w2": w2}, P)
    got = kops.grouped_swiglu(x, sub["w1"], sub["w3"], sub["w2"], cf, cm,
                              p_factor=P, block_c=bc, block_f=bf)
    # oracle: full-width weights with the minor region starting at the
    # first sub-expert boundary
    want = kref.grouped_swiglu_ref(x, w1, w3, w2, cf, cm,
                                   n_minor_start=f // P)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_kernel_explicit_n_minor_start_disables_split(rng):
    """n_minor_start == f treats every neuron as MAJOR: counts_major rows
    compute the full group (the S-ETP local-buffer contract)."""
    E, C, d, f = 2, 16, 16, 32
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (E, C, d)) * 0.5
    w1 = jax.random.normal(ks[1], (E, d, f)) * 0.1
    w3 = jax.random.normal(ks[2], (E, d, f)) * 0.1
    w2 = jax.random.normal(ks[3], (E, f, d)) * 0.1
    cf = jnp.asarray([3, 0])
    cm = jnp.asarray([5, 7])
    got = kops.grouped_swiglu(x, w1, w3, w2, cf, cm, n_minor_start=f,
                              block_c=8, block_f=16)
    want = kops.grouped_swiglu(x, w1, w3, w2, cf + cm, None,
                               block_c=8, block_f=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
