"""Streamed fused-pipeline kernel: property coverage vs the buffer-path
oracle and the resident variant.

The streamed kernel (scalar-prefetch SMEM maps, x/out in HBM behind
double-buffered DMA) shares math and accumulation order with the resident
variant it replaced, so the two must agree BIT-FOR-BIT on every layout;
both match the buffer path to tolerance only (per-token K-sum order
differs). Property sweep covers ragged ``T % block_c != 0``, empty
experts, P in {1, 2}, and capacity-overflow pressure — plus a pinned
representative grid naming each edge. Uses real hypothesis when installed
and the deterministic ``_hypothesis_compat`` sweep otherwise (this
container ships without it).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch as D
from repro.core import gating, moe
from repro.core.policy import TwoTDrop, make_policy
from repro.kernels import ops as kops

try:
    import hypothesis
    from hypothesis import given, strategies as st

    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=20,
        suppress_health_check=list(hypothesis.HealthCheck))
    hypothesis.settings.load_profile("ci")
except ImportError:
    from _hypothesis_compat import st, given  # noqa: F401


def _check_case(seed: int, T: int, E: int, P: int, K: int, block_c: int,
                cap: int, hot: bool = False):
    """One property case: random routing + weights on a (possibly ragged,
    overflowing, or mostly-empty) layout. Streamed must equal resident
    bit-for-bit and match the buffer-path kernel oracle."""
    d, fsub = 16, 32
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    w1 = jax.random.normal(ks[0], (E * P, d, fsub)) * 0.1
    w3 = jax.random.normal(ks[1], (E * P, d, fsub)) * 0.1
    w2 = jax.random.normal(ks[2], (E * P, fsub, d)) * 0.1
    x = jax.random.normal(ks[3], (T, d))
    hi = max(1, E // 4) if hot else E      # hot: most experts stay empty
    group = jax.random.randint(ks[4], (T, K), 0, hi)
    keep = jax.random.bernoulli(ks[5], 0.85, (T, K))
    wts = jax.random.uniform(ks[6], (T, K))
    major = (jax.random.bernoulli(ks[7], 0.3, (T, K)) & keep) \
        if P > 1 else None
    plan = D.sort_dispatch(group, keep, n_groups=E, capacity=cap,
                           major_only=major)
    w = wts * keep
    cf, cm = plan.kernel_counts(cap)
    tok_s, w_s = D.sorted_pair_arrays(plan, w, index_div=K, pad=block_c)
    nms = None if P > 1 else fsub

    # oracle: buffer path (gather -> grouped_swiglu -> unpermute + combine)
    buf = D.gather_rows(x, plan, cap, index_div=K)
    out_buf = kops.grouped_swiglu(buf, w1, w3, w2, counts_full=cf,
                                  counts_major=cm, p_factor=P,
                                  n_minor_start=nms, block_c=block_c,
                                  block_f=32)
    gathered = D.unpermute(out_buf, plan)
    y_ref = (gathered * w.reshape(-1)[:, None]).reshape(T, K, d).sum(1)

    args = (x, w1, w3, w2, plan.group_offsets, cf, cm, tok_s, w_s)
    kw = dict(capacity=cap, p_factor=P, n_minor_start=nms,
              block_c=block_c, block_f=32)
    y_s = kops.fused_moe_pipeline(*args, streamed=True, **kw)
    y_r = kops.fused_moe_pipeline(*args, streamed=False, **kw)
    assert (np.asarray(y_s) == np.asarray(y_r)).all(), (
        f"streamed DMA staging perturbed bits vs resident variant "
        f"(T={T} E={E} P={P} K={K} block_c={block_c} cap={cap})")
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_ref),
                               atol=1e-4)


# (seed, T, E, P, K, block_c, cap, hot) — each row names the edge it pins
GRID = [
    (1, 37, 4, 1, 2, 8, 12, False),    # ragged T, overflow pressure
    (2, 40, 3, 1, 2, 16, 48, False),   # ragged expert count, ample cap
    (3, 6, 8, 1, 1, 8, 8, True),       # T < block_c + most experts empty
    (4, 64, 4, 2, 2, 16, 24, False),   # P=2 mode-grouped, overflow
    (5, 33, 4, 2, 2, 8, 64, False),    # P=2 ragged, no overflow
    (6, 128, 8, 2, 2, 32, 16, True),   # P=2 hot experts, heavy overflow
]


@pytest.mark.parametrize("seed,T,E,P,K,block_c,cap,hot", GRID)
def test_streamed_property_grid(seed, T, E, P, K, block_c, cap, hot):
    _check_case(seed, T, E, P, K, block_c, cap, hot)


@st.composite
def streamed_cases(draw):
    # T sampled from a pinned ragged/aligned set (not a free range): the
    # interpret kernels recompile per distinct static shape, so a bounded
    # shape vocabulary keeps the sweep's wall-clock sane via jit caching
    return (draw(st.integers(0, 2 ** 16)),          # seed
            draw(st.sampled_from([5, 13, 37, 40, 64])),  # T, mostly ragged
            draw(st.sampled_from([4, 8])),          # E
            draw(st.sampled_from([1, 2])),          # P
            draw(st.integers(1, 2)),                # K
            draw(st.sampled_from([8, 16])),         # block_c
            draw(st.sampled_from([8, 64])),         # capacity
            draw(st.booleans()))                    # hot (empty experts)


@given(streamed_cases())
def test_streamed_property_sweep(case):
    _check_case(*case)


# ---------------------------------------------------------------------------
# Production fused layout: streamed vs resident at the dispatch level
# ---------------------------------------------------------------------------

def _prod_setup(moe_cfg, moe_params, calib_x):
    from benchmarks.common import sharp_router_params
    params = sharp_router_params(moe_params)
    pol = TwoTDrop(partition_p=2, use_kernel=True, fused_pipeline=True)
    prepared, _ = pol.prepare(params, moe_cfg, calib_x)
    r = gating.route(calib_x, params["wg"], moe_cfg.top_k,
                     moe_cfg.router_norm_topk)
    t1 = float(jnp.quantile(r.norm_score, 0.35))
    pol = dataclasses.replace(pol, t_major=t1 - 0.02, t_minor=t1 + 0.02)
    return prepared, pol, pol.route(prepared, calib_x, moe_cfg)


@pytest.mark.parametrize("capacity", [None, 8])   # ample / overflowing
def test_streamed_equals_resident_production_layout(moe_cfg, moe_params,
                                                    calib_x, capacity):
    prepared, pol, pairs = _prod_setup(moe_cfg, moe_params, calib_x)
    cap = capacity or calib_x.shape[0]
    y_s, ov_s = moe.moe_forward_dispatch(
        prepared, calib_x, moe_cfg, pairs=pairs, capacity=cap,
        fused_pipeline=True, mode_grouped=True, return_overflow=True)
    y_r, ov_r = moe.moe_forward_dispatch(
        prepared, calib_x, moe_cfg, pairs=pairs, capacity=cap,
        fused_pipeline=True, fused_streamed=False, mode_grouped=True,
        return_overflow=True)
    assert (np.asarray(y_s) == np.asarray(y_r)).all()
    assert int(ov_s) == int(ov_r)
    if capacity is not None:
        assert int(ov_s) > 0    # the pressure case must actually overflow


# ---------------------------------------------------------------------------
# Auto heuristic: default-on selection + no retrace on threshold change
# ---------------------------------------------------------------------------

def test_prefer_fused_pipeline_table():
    """Non-CPU backends: always fused (the streamed kernel's VMEM working
    set is T-independent). CPU interpret: fused iff the buffer path would
    also run interpreted kernels (BENCH_moe_pipeline.json trajectory)."""
    assert D.prefer_fused_pipeline(8192, 64, backend="tpu")
    assert D.prefer_fused_pipeline(1, 4, backend="gpu")
    assert D.prefer_fused_pipeline(8192, 4, use_kernel=True, backend="cpu")
    assert not D.prefer_fused_pipeline(8192, 4, use_kernel=False,
                                       backend="cpu")
    assert not D.prefer_fused_pipeline(64, 8, backend="cpu")


def test_auto_hint_no_retrace_on_threshold_change(moe_cfg, moe_params,
                                                  calib_x):
    """fused_pipeline=None resolves INSIDE jit from static shape/backend
    facts only — flipping traced threshold leaves must not retrace."""
    prepared, pol, _ = _prod_setup(moe_cfg, moe_params, calib_x)
    pol = dataclasses.replace(pol, fused_pipeline=None)
    traces = []

    @jax.jit
    def fwd(params, x, policy):
        traces.append(1)
        pairs = policy.route(params, x, moe_cfg)
        return moe.moe_forward_dispatch(
            params, x, moe_cfg, pairs=pairs, capacity=x.shape[0],
            use_kernel=True, mode_grouped=policy.kernel_mode_grouping,
            fused_pipeline=policy.fused_pipeline)

    x = calib_x[:32]
    fwd(prepared, x, pol)
    assert len(traces) == 1
    moved = dataclasses.replace(pol, t_major=pol.t_major + 0.01,
                                t_minor=pol.t_minor + 0.01)
    fwd(prepared, x, moved)
    assert len(traces) == 1, "threshold change must not retrace"


# ---------------------------------------------------------------------------
# Metrics counters ride unchanged through the streamed path
# ---------------------------------------------------------------------------

def test_metrics_counters_parity_fused_vs_buffer(moe_cfg, moe_params,
                                                 calib_x):
    """kept_full/kept_major/dropped_pairs come from the routing (shared),
    but overflow_pairs and the expert_load histogram flow through the
    dispatch path — the streamed fused path must report the same stats
    dict as the buffer path on the production fused layout."""
    from benchmarks.common import sharp_router_params
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as TF
    params = sharp_router_params(moe_params)
    pol = make_policy("2t", moe_cfg.dualsparse, use_kernel=True,
                      fused_pipeline=True)
    prepared, pol_f = pol.prepare(params, moe_cfg, calib_x)
    pol_b = dataclasses.replace(pol_f, fused_pipeline=False)
    x = calib_x[:64].reshape(1, 64, moe_cfg.d_model)
    mesh = make_host_mesh(1)

    def stats_for(policy):
        dist = TF.DistContext(mesh=mesh, moe_impl="dispatch", policy=policy)
        y, _, stats = TF._moe_forward(prepared, x, moe_cfg, dist,
                                      collect=True)
        return y, stats

    y_f, st_f = stats_for(pol_f)
    y_b, st_b = stats_for(pol_b)
    for key in ("kept_full", "kept_major", "dropped_pairs",
                "overflow_pairs"):
        assert int(st_f[key]) == int(st_b[key]), key
    np.testing.assert_array_equal(np.asarray(st_f["expert_load"]),
                                  np.asarray(st_b["expert_load"]))
    np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_b), atol=1e-4)
