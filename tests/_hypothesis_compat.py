"""Minimal stand-in for the slice of the Hypothesis API that
``test_property.py`` uses, so property tests still run (as deterministic
randomized sweeps) in environments where ``hypothesis`` is not installed.

Covered: ``given``, ``strategies.{sampled_from,integers,floats,booleans,
composite}``. Each ``@given`` test runs ``MAX_EXAMPLES`` examples drawn from
a PRNG seeded by the test name, so failures are reproducible run-to-run.
This is intentionally NOT a shrinker/fuzzer — install hypothesis to get the
real thing; the import gate in test_property.py prefers it when present.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import zlib

MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, sample_fn):
        self._sample_fn = sample_fn

    def sample(self, rng: random.Random):
        return self._sample_fn(rng)


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda r: seq[r.randrange(len(seq))])


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda r: r.random() < 0.5)


def composite(fn):
    def builder(*args, **kwargs):
        return _Strategy(
            lambda r: fn(lambda strat: strat.sample(r), *args, **kwargs))
    return builder


def given(*strategies):
    def decorator(f):
        base_seed = zlib.crc32(f.__qualname__.encode())

        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            for i in range(MAX_EXAMPLES):
                rng = random.Random(base_seed * 100003 + i)
                drawn = [s.sample(rng) for s in strategies]
                try:
                    f(*args, *drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} for {f.__qualname__}: "
                        f"{drawn!r}") from e

        # hide the strategy-supplied params from pytest's fixture resolution
        # (real hypothesis does the same via its own signature rewrite)
        wrapper.__signature__ = inspect.Signature(parameters=[])
        return wrapper
    return decorator


class _SettingsMeta(type):
    def __iter__(cls):          # list(HealthCheck) in the real API
        return iter(())


class HealthCheck(metaclass=_SettingsMeta):
    pass


class settings(metaclass=_SettingsMeta):
    """No-op settings: profiles are irrelevant to the fallback sweep."""

    def __init__(self, *args, **kwargs):
        pass

    def __call__(self, f):
        return f

    @staticmethod
    def register_profile(name, *args, **kwargs):
        pass

    @staticmethod
    def load_profile(name):
        pass


# ``from _hypothesis_compat import st`` mirrors ``hypothesis.strategies``.
st = sys.modules[__name__]
