"""Per-architecture smoke tests (assignment requirement): REDUCED variant of
each family runs one train step and one prefill->decode step on CPU with
finite outputs and correct shapes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import model as M
from repro.optim import adamw


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(rng, arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    params = M.init_params(rng, cfg)
    batch = M.make_batch(rng, cfg, 2, 16, "train")
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    step = M.make_train_step(cfg, opt)
    new_params, opt_state, loss = step(params, opt_state, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert float(loss) < 2 * np.log(cfg.vocab_size)
    # params actually changed
    diff = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert diff > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_prefill_decode(rng, arch):
    cfg = get_config(arch).reduced()
    B, S = 2, 12
    params = M.init_params(rng, cfg)
    batch = M.make_batch(rng, cfg, B, S, "prefill")
    ctx = M.context_len_for(cfg, S, 4)
    prefill = M.make_prefill_step(cfg, cache_len=ctx)
    logits, cache = prefill(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    serve = M.make_serve_step(cfg)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for _ in range(3):
        logits1, cache = serve(params, tok, cache)
        assert logits1.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits1).all())
        tok = jnp.argmax(logits1[:, -1:], -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["granite-20b", "zamba2-7b",
                                  "qwen3-moe-30b-a3b"])
def test_smoke_windowed_decode(rng, arch):
    """Sliding-window decode variant used by long_500k."""
    cfg = get_config(arch).reduced()
    w = cfg.sliding_window or 16
    B, S = 2, 12
    params = M.init_params(rng, cfg)
    batch = M.make_batch(rng, cfg, B, S, "prefill")
    prefill = M.make_prefill_step(cfg, cache_len=S + 4, window=w)
    logits, cache = prefill(params, batch)
    serve = M.make_serve_step(cfg, window=w)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    logits1, cache = serve(params, tok, cache)
    assert bool(jnp.isfinite(logits1).all())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_dims(arch):
    """Full configs carry the exact assigned dimensions."""
    cfg = get_config(arch)
    expected = {
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected
    if arch == "qwen3-moe-30b-a3b":
        assert (cfg.n_experts, cfg.top_k) == (128, 8)
    if arch == "dbrx-132b":
        assert (cfg.n_experts, cfg.top_k) == (16, 4)
    if arch in ("zamba2-7b",):
        assert cfg.ssm_state == 64
    if arch == "mamba2-370m":
        assert cfg.ssm_state == 128


@pytest.mark.parametrize("arch,lo,hi", [
    ("qwen2-7b", 6.5e9, 8.5e9), ("dbrx-132b", 1.2e11, 1.45e11),
    ("mamba2-370m", 3.0e8, 4.5e8), ("granite-20b", 1.8e10, 2.2e10),
    ("qwen3-moe-30b-a3b", 2.8e10, 3.3e10),
])
def test_param_counts_nominal(arch, lo, hi):
    assert lo < get_config(arch).n_params() < hi


def test_transform_params_for_dualsparse_warns_deprecated(rng):
    """The shim over SparsityPolicy.prepare must announce its deprecation
    so remaining callers migrate to make_policy(...).prepare(...)."""
    cfg = dataclasses.replace(get_config("olmoe-lite").reduced(),
                              n_layers=1)
    params = M.init_params(rng, cfg)
    calib = jax.random.normal(rng, (8, cfg.d_model))
    with pytest.warns(DeprecationWarning, match="make_policy"):
        out = M.transform_params_for_dualsparse(params, cfg, calib)
    assert set(out) == set(params)
