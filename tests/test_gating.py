import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gating


def test_topk_routing_selects_highest(rng):
    logits = jax.random.normal(rng, (32, 16))
    r = gating.top_k_routing(logits, 4, renorm=True)
    probs = jax.nn.softmax(logits, -1)
    top = jnp.sort(probs, axis=-1)[:, -4:]
    np.testing.assert_allclose(np.sort(np.asarray(
        jnp.take_along_axis(probs, r.idx, 1)), axis=-1), np.asarray(top),
        rtol=1e-6)


def test_normalized_scores_sum_to_one(rng):
    logits = jax.random.normal(rng, (64, 32)) * 3
    r = gating.top_k_routing(logits, 8, renorm=False)
    np.testing.assert_allclose(np.asarray(r.norm_score.sum(-1)), 1.0,
                               rtol=1e-5)
    # combine weights are the raw softmax scores when renorm=False
    assert float(r.combine.sum(-1).max()) <= 1.0 + 1e-5


def test_renorm_combine_equals_norm_score(rng):
    logits = jax.random.normal(rng, (16, 8))
    r = gating.top_k_routing(logits, 2, renorm=True)
    np.testing.assert_array_equal(np.asarray(r.combine),
                                  np.asarray(r.norm_score))


def test_expert_histogram_counts(rng):
    idx = jnp.array([[0, 1], [1, 2], [1, 3]])
    hist = gating.expert_histogram(idx, 4)
    np.testing.assert_array_equal(np.asarray(hist), [1, 3, 1, 1])
    keep = jnp.array([[True, False], [True, True], [False, True]])
    # kept pairs: (0,e0), (1,e1), (1,e2), (2,e3)
    hist = gating.expert_histogram(idx, 4, keep=keep)
    np.testing.assert_array_equal(np.asarray(hist), [1, 1, 1, 1])


def test_aux_loss_uniform_is_one(rng):
    # perfectly uniform routing -> loss == n_experts * E[1/E * 1/E] * E = 1
    T, E, K = 1024, 8, 1
    probs = jnp.full((T, E), 1.0 / E)
    idx = jnp.tile(jnp.arange(E), T // E + 1)[:T][:, None]
    loss = gating.load_balance_aux_loss(probs, idx, E)
    np.testing.assert_allclose(float(loss), 1.0, rtol=1e-5)
