"""Continuous-batching engine: correctness vs the synchronized baseline,
mid-decode admission without retracing, and slot retirement/reuse."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving import (ContinuousBatchingEngine, GenerationConfig,
                           ServingEngine)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("mixtral-8x7b-lite")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, mults=(7, 11, 13, 17, 5, 3)):
    return [np.asarray((np.arange(L) * m) % cfg.vocab_size)
            for L, m in zip(lens, mults)]


def test_continuous_matches_synchronized_greedy(served):
    """Identical greedy requests produce identical tokens on both engines
    (requires exact MoE dispatch so outputs are batch-composition-invariant:
    more requests than slots => mid-run admission must not perturb tokens)."""
    cfg, params = served
    L, new = 12, 6
    prompts = _prompts(cfg, [L] * 5)
    gen = GenerationConfig(max_new_tokens=new)
    sync = ServingEngine(cfg, params, batch_size=5, max_prompt_len=L,
                         max_new_tokens=new, exact_moe=True)
    rs = sync.generate(prompts, gen)
    cont = ContinuousBatchingEngine(cfg, params, n_slots=3, max_prompt_len=L,
                                    max_new_tokens=new)
    rc = cont.generate(prompts, gen)
    assert [r.tokens for r in rs] == [r.tokens for r in rc]
    assert all(len(r.tokens) == new for r in rc)


def test_continuous_ragged_matches_isolated_requests(served):
    """Mixed-length prompts decoded together in shared slots must match each
    request served entirely alone — per-slot positions and ragged KV masking
    give full request isolation."""
    cfg, params = served
    lens = [6, 12, 9, 12]
    new = 5
    prompts = _prompts(cfg, lens)
    gen = GenerationConfig(max_new_tokens=new)
    solo = ServingEngine(cfg, params, batch_size=1, max_prompt_len=max(lens),
                         max_new_tokens=new, exact_moe=True)
    expect = [solo.generate([p], gen)[0].tokens for p in prompts]
    cont = ContinuousBatchingEngine(cfg, params, n_slots=2,
                                    max_prompt_len=max(lens),
                                    max_new_tokens=new)
    rc = cont.generate(prompts, gen)
    assert [r.tokens for r in rc] == expect


def test_mid_decode_admission_without_retrace(served):
    """A request submitted while others are mid-decode is admitted into a
    free slot and completes — and neither the jitted decode step nor the
    prefill-insert retraces on slot churn (fixed shapes by construction)."""
    cfg, params = served
    L, new = 10, 8
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_prompt_len=L,
                                   max_new_tokens=new)
    prompts = _prompts(cfg, [L] * 3)
    gen = GenerationConfig(max_new_tokens=new)
    u0 = eng.submit(prompts[0], gen)
    u1 = eng.submit(prompts[1], gen)
    for _ in range(3):                      # both slots now mid-decode
        eng.step()
    traces_after_warmup = (eng.prefill_traces, eng.decode_traces)
    assert eng.free_slots == 0
    u2 = eng.submit(prompts[2], gen)        # queued: no slot free yet
    assert eng.queued == 1
    eng.step()
    assert eng.queued == 1                  # still waiting for a retirement
    eng.run()
    for uid in (u0, u1, u2):
        assert len(eng.result(uid).tokens) == new
    # the late request went through admission (prefill-insert) + decode with
    # ZERO new traces — the continuous engine's core fixed-shape guarantee
    assert (eng.prefill_traces, eng.decode_traces) == traces_after_warmup
    assert eng.prefill_traces == 1 and eng.decode_traces == 1
    assert eng.n_admitted == 3 and eng.n_retired == 3


def test_eos_retirement_frees_slot_for_queued_request(served):
    """Per-request EOS retires a slot early; a queued request then fills it
    (scheduler reuse), and the EOS-truncated request keeps the EOS token as
    its last emitted token (synchronized-engine semantics)."""
    cfg, params = served
    L, new = 12, 8
    prompts = _prompts(cfg, [L, L])
    gen = GenerationConfig(max_new_tokens=new)
    # learn request 0's greedy continuation, then replay with an EOS pinned
    # to the first token that doesn't repeat an earlier one, so the request
    # must retire after exactly cut+1 emissions (mid-run, before its budget)
    probe = ContinuousBatchingEngine(cfg, params, n_slots=1,
                                     max_prompt_len=L, max_new_tokens=new)
    full = probe.generate([prompts[0]], gen)[0].tokens
    cut = next((i for i in range(1, len(full) - 1)
                if full[i] not in full[:i]), None)
    assert cut is not None, f"fully periodic greedy loop: {full}"
    eos = full[cut]

    eng = ContinuousBatchingEngine(cfg, params, n_slots=1, max_prompt_len=L,
                                   max_new_tokens=new)
    gen_eos = GenerationConfig(max_new_tokens=new, eos_token=eos)
    u0 = eng.submit(prompts[0], gen_eos)
    u1 = eng.submit(prompts[1], gen_eos)
    eng.step()                               # admits only request 0 (1 slot)
    assert eng.queued == 1
    eng.run()
    r0, r1 = eng.result(u0), eng.result(u1)
    assert r0.tokens == full[:cut + 1] and r0.tokens[-1] == eos
    assert len(r1.tokens) >= 1               # admitted after the retirement
    assert eng.n_admitted == 2 and eng.max_concurrency == 1


def test_timed_admission_respects_arrivals(served):
    """generate_timed submits requests only once the clock passes their
    arrival times and reports latency = finish - arrival."""
    cfg, params = served
    L, new = 8, 3
    prompts = _prompts(cfg, [L, L, L])
    arrivals = [(0.0, prompts[0], GenerationConfig(max_new_tokens=new)),
                (0.05, prompts[1], GenerationConfig(max_new_tokens=new)),
                (0.1, prompts[2], GenerationConfig(max_new_tokens=new))]
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_prompt_len=L,
                                   max_new_tokens=new)
    res = eng.generate_timed(arrivals)
    assert [r.submitted_s for r in res] == [0.0, 0.05, 0.1]
    assert all(len(r.tokens) == new for r in res)
    assert all(r.finished_s >= r.submitted_s for r in res)


def test_oversized_requests_rejected(served):
    cfg, params = served
    eng = ContinuousBatchingEngine(cfg, params, n_slots=1, max_prompt_len=8,
                                   max_new_tokens=4)
    with pytest.raises(ValueError):
        eng.submit(np.arange(9), GenerationConfig(max_new_tokens=2))
    with pytest.raises(ValueError):
        eng.submit(np.arange(4), GenerationConfig(max_new_tokens=5))
