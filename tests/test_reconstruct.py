"""Paper §4.2(b): neuron-importance profiling + reconstruction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import drop, gating, moe, reconstruct


@pytest.mark.parametrize("method", reconstruct.IMPORTANCE_METHODS)
def test_importance_shapes_and_methods(rng, moe_cfg, moe_params, calib_x,
                                       method):
    imp = reconstruct.neuron_importance(moe_params, calib_x, moe_cfg, method)
    assert imp.shape == (moe_cfg.n_experts, moe_cfg.d_expert)
    if method.startswith("abs"):
        assert float(imp.min()) >= 0.0


def test_abs_methods_dominate_signed(rng, moe_cfg, moe_params, calib_x):
    """|sum| <= sum|.| elementwise (the paper's cancellation argument)."""
    s = reconstruct.neuron_importance(moe_params, calib_x, moe_cfg, "gate")
    a = reconstruct.neuron_importance(moe_params, calib_x, moe_cfg,
                                      "abs_gate")
    assert np.all(np.abs(np.asarray(s)) <= np.asarray(a) + 1e-5)


def test_reorder_is_exact(rng, moe_cfg, moe_params, calib_x):
    imp = reconstruct.neuron_importance(moe_params, calib_x, moe_cfg)
    reordered = reconstruct.reorder_neurons(moe_params, imp)
    x = jax.random.normal(rng, (32, moe_cfg.d_model))
    y0 = moe.moe_forward_ref(moe_params, x, moe_cfg)
    y1 = moe.moe_forward_ref(reordered, x, moe_cfg)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)


def test_reconstruct_major_holds_importance(rng, moe_cfg, moe_params,
                                            calib_x):
    """After partition_and_reconstruct, the major sub-expert (even ids) must
    carry at least as much total importance as the minor one."""
    rec = reconstruct.partition_and_reconstruct(moe_params, calib_x, moe_cfg,
                                                p=2)
    # recompute importance on the reconstructed sub-experts via gate metric
    g_major = jnp.abs(jax.nn.silu(
        jnp.einsum("td,edf->etf", calib_x, rec["w1"][0::2]))).sum((1, 2))
    g_minor = jnp.abs(jax.nn.silu(
        jnp.einsum("td,edf->etf", calib_x, rec["w1"][1::2]))).sum((1, 2))
    assert np.all(np.asarray(g_major) >= np.asarray(g_minor) * 0.99)


def test_reconstruct_no_drop_exact(rng, moe_cfg, moe_params, calib_x):
    rec = reconstruct.partition_and_reconstruct(moe_params, calib_x, moe_cfg,
                                                p=2)
    x = jax.random.normal(rng, (32, moe_cfg.d_model))
    r = gating.route(x, moe_params["wg"], moe_cfg.top_k,
                     moe_cfg.router_norm_topk)
    pairs = drop.expand_pairs_2t(r.idx, r.combine, r.norm_score, 2,
                                 -1.0, -1.0)
    y0 = moe.moe_forward_ref(moe_params, x, moe_cfg)
    y1 = moe.moe_forward_ref(rec, x, moe_cfg, pairs=pairs)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)


def test_partition_reconstruction_regression_dispatch(rng, moe_cfg,
                                                      moe_params, calib_x):
    """Regression pin for the paper's core §3 invariant on the PRODUCTION
    path: the capacity-dispatch forward over partitioned+reconstructed
    experts with no dropping must agree with the dense reference over the
    ORIGINAL experts within fp tolerance. Guards partition/reconstruct and
    the dispatch machinery against future kernel refactors."""
    rec = reconstruct.partition_and_reconstruct(moe_params, calib_x, moe_cfg,
                                                p=2)
    x = jax.random.normal(rng, (40, moe_cfg.d_model))
    y0 = moe.moe_forward_ref(moe_params, x, moe_cfg)
    r = gating.route(x, moe_params["wg"], moe_cfg.top_k,
                     moe_cfg.router_norm_topk)
    pairs = drop.expand_pairs_2t(r.idx, r.combine, r.norm_score, 2,
                                 -1.0, -1.0)
    # capacity == T: no overflow drops, so dispatch must be exact
    y1 = moe.moe_forward_dispatch(rec, x, moe_cfg, pairs=pairs,
                                  capacity=x.shape[0])
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-4)


def test_partition_reconstruction_regression_model(rng, moe_cfg):
    """Same §3 invariant end-to-end through the model: full-model forward
    with transformed params (2T thresholds disabled, exact dispatch) matches
    the untransformed model's logits within fp tolerance."""
    import dataclasses as dc
    from repro.core.policy import TwoTDrop
    from repro.data.pipeline import calibration_activations
    from repro.models import model as M
    from repro.serving import exact_moe_dist

    # thresholds below any score => nothing drops; exact capacity => no
    # overflow; outputs must then be preserved by partition+reconstruction
    cfg = moe_cfg
    pol = TwoTDrop(partition_p=2, t_major=-1.0, t_minor=-1.0,
                   exact_capacity=True)
    params = M.init_params(rng, cfg)
    calib = calibration_activations(jax.random.fold_in(rng, 3), 128,
                                    cfg.d_model)
    tparams, pol = pol.prepare(params, cfg, calib)
    batch = M.make_batch(rng, cfg, 2, 16, "serve")
    from repro.models import transformer as T
    base = T.forward(params, batch, cfg, dist=exact_moe_dist(None))
    dist = dc.replace(exact_moe_dist(None), policy=pol)
    recon = T.forward(tparams, batch, cfg, dist=dist)
    np.testing.assert_allclose(np.asarray(base), np.asarray(recon),
                               atol=2e-3, rtol=1e-3)


def test_major_only_better_than_minor_only(rng, moe_cfg, moe_params,
                                           calib_x):
    """Computing only the MAJOR halves must approximate the full output
    better than computing only the MINOR halves — the reason reconstruction
    reduces accuracy loss (paper Table 2)."""
    rec = reconstruct.partition_and_reconstruct(moe_params, calib_x, moe_cfg,
                                                p=2)
    x = calib_x[:48]
    y_full = moe.moe_forward_ref(moe_params, x, moe_cfg)
    r = gating.route(x, moe_params["wg"], moe_cfg.top_k,
                     moe_cfg.router_norm_topk)
    base = drop.expand_pairs_2t(r.idx, r.combine, r.norm_score, 2, -1., -1.)
    is_major = (base.idx % 2) == 0
    pairs_major = base._replace(keep=is_major)
    pairs_minor = base._replace(keep=~is_major)
    y_major = moe.moe_forward_ref(rec, x, moe_cfg, pairs=pairs_major)
    y_minor = moe.moe_forward_ref(rec, x, moe_cfg, pairs=pairs_minor)
    err_major = float(jnp.mean((y_major - y_full) ** 2))
    err_minor = float(jnp.mean((y_minor - y_full) ** 2))
    assert err_major < err_minor
