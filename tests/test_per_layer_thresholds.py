"""Beyond-paper: per-layer threshold calibration (paper §5.3.3 future work)."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import drop, moe
from repro.data import pipeline
from repro.models import model as M


def test_calibrate_threshold_hits_target(rng):
    scores = jax.random.uniform(rng, (4096, 8))
    for target in (0.1, 0.25, 0.5):
        t = drop.calibrate_threshold(scores, target)
        got = float(jnp.mean(scores <= t))
        assert abs(got - target) < 0.02


def test_per_layer_thresholds_equalize_drop(rng):
    """A single global threshold gives wildly different per-layer drop rates
    (Fig 12); calibrated per-layer thresholds equalize them."""
    cfg = get_config("olmoe-lite")
    key = rng
    # synthetic per-layer score distributions with different spreads
    layer_scores = [jax.random.beta(jax.random.fold_in(key, i),
                                    2.0, 2.0 + 3 * i, (2048, 8))
                    for i in range(4)]
    target = 0.25
    ts = drop.calibrate_per_layer_thresholds(layer_scores, target)
    assert ts.shape == (4, 2)
    for s, (tm, tn) in zip(layer_scores, ts):
        t1 = (tm + tn) / 2
        rate = float(jnp.mean(s <= t1))
        assert abs(rate - target) < 0.03
    # while the single global threshold misses badly on at least one layer
    t_global = drop.calibrate_threshold(jnp.concatenate(
        [s.reshape(-1) for s in layer_scores]), target)
    rates = [float(jnp.mean(s <= t_global)) for s in layer_scores]
    assert max(abs(r - target) for r in rates) > 0.05


def test_transform_with_target_drop_rate(rng):
    cfg = get_config("olmoe-lite")
    params = M.init_params(rng, cfg)
    calib = pipeline.calibration_activations(jax.random.fold_in(rng, 1),
                                             512, cfg.d_model)
    tparams = M.transform_params_for_dualsparse(params, cfg, calib,
                                                target_drop_rate=0.25)
    th = tparams["blocks"]["moe"]["thresholds"]
    assert th.shape == (cfg.n_layers, 2)
    assert bool((th[:, 1] >= th[:, 0]).all())
    # the routed drop rate per layer is near the target
    for layer in range(cfg.n_layers):
        moe_p = jax.tree.map(lambda a: a[layer], tparams["blocks"]["moe"])
        pairs = moe.route_dualsparse(moe_p, calib, cfg)
        fs = float(drop.flops_saved_fraction(pairs.modes))
        assert abs(fs - 0.25) < 0.08, (layer, fs)
    # and the model still runs end to end with the stored thresholds
    from repro.core.policy import make_policy
    from repro.models.transformer import DistContext
    from repro.launch.mesh import make_host_mesh
    dist = DistContext(mesh=make_host_mesh(1), moe_impl="dispatch",
                       policy=make_policy("per_layer", cfg.dualsparse,
                                          drop_target=0.25))
    batch = M.make_batch(rng, cfg, 2, 16, "train")
    loss = M.loss_fn(tparams, batch, cfg, dist=dist)
    assert bool(jnp.isfinite(loss))
