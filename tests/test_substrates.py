"""Data pipeline, optimizer, checkpointing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import pipeline
from repro.optim import adamw, clip_by_global_norm, cosine_schedule


def test_pipeline_deterministic(moe_cfg):
    loader = pipeline.make_loader(moe_cfg, 4, 32, seed=7)
    b1, b2 = loader.get_batch(3), loader.get_batch(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = loader.get_batch(4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_pipeline_targets_shifted(moe_cfg):
    loader = pipeline.make_loader(moe_cfg, 2, 16)
    b = loader.get_batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))


def test_pipeline_zipf_skew(moe_cfg):
    loader = pipeline.make_loader(moe_cfg, 16, 256)
    toks = np.asarray(loader.get_batch(0)["tokens"]).ravel()
    # low ids should be much more frequent than high ids
    assert (toks < 50).mean() > (toks > moe_cfg.vocab_size - 50).mean() * 3


def test_calibration_activations_anisotropic(rng):
    x = pipeline.calibration_activations(rng, 512, 64)
    var = np.var(np.asarray(x), axis=0)
    assert var.max() / var.min() > 3.0


def test_cosine_schedule():
    lr = cosine_schedule(1.0, 100, warmup=10)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) < 0.2
    assert float(lr(55)) < float(lr(11))


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(clipped["a"])), 1.0,
                               rtol=1e-5)


def test_adamw_reduces_quadratic():
    opt = adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    st = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        up, st = opt.update(g, st, params)
        params = jax.tree.map(lambda p, u: p + u, params, up)
    assert float(loss(params)) < 1e-2


def test_checkpoint_roundtrip_and_sharding(tmp_path, rng):
    tree = {"a": jax.random.normal(rng, (128, 64)),
            "nested": {"b": jnp.arange(10), "c": jnp.float32(3.5)}}
    ckpt.save_checkpoint(str(tmp_path), 5, tree, max_shard_bytes=1024)
    # multiple shards were written
    import json
    man = json.load(open(tmp_path / "step_00000005" / "manifest.json"))
    assert len(man["shards"]) >= 2
    restored = ckpt.restore_checkpoint(str(tmp_path), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_missing(tmp_path):
    assert ckpt.latest_step(str(tmp_path)) is None
    ckpt.save_checkpoint(str(tmp_path), 1, {"x": jnp.ones(2)})
    ckpt.save_checkpoint(str(tmp_path), 7, {"x": jnp.ones(2)})
    assert ckpt.latest_step(str(tmp_path)) == 7
    with pytest.raises(ValueError):
        ckpt.restore_checkpoint(str(tmp_path), {"x": jnp.ones(3)})
