"""Fused Pallas MoE pipeline (dispatch -> expert FFN -> combine in ONE
kernel) vs the retained buffer-path oracle, plus the overflow-unit and
dispatch-heuristic regressions that ride with it (ROADMAP item 4)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as D
from repro.core import drop, gating, moe
from repro.core.policy import NoDrop, TwoTDrop
from repro.kernels import ops as kops


def _two_t_setup(rng, moe_cfg, moe_params, calib_x, fused: bool = True):
    """Prepared 2T params + thresholds that actually produce mode-1 pairs
    (router sharpened so normalized scores spread)."""
    from benchmarks.common import sharp_router_params
    params = sharp_router_params(moe_params)
    pol = TwoTDrop(partition_p=2, use_kernel=True, fused_pipeline=fused)
    prepared, _ = pol.prepare(params, moe_cfg, calib_x)
    r = gating.route(calib_x, params["wg"], moe_cfg.top_k,
                     moe_cfg.router_norm_topk)
    t1 = float(jnp.quantile(r.norm_score, 0.35))
    pol = dataclasses.replace(pol, t_major=t1 - 0.02, t_minor=t1 + 0.02)
    pairs = pol.route(prepared, calib_x, moe_cfg)
    modes = np.asarray(pairs.modes)
    assert (modes == drop.MODE_MAJOR).sum() > 0, \
        "setup must yield MAJOR-only pairs"
    return prepared, pol, pairs


# ---------------------------------------------------------------------------
# Bit-consistency vs the buffer-path oracle
# ---------------------------------------------------------------------------

def test_fused_matches_oracle_p2_mode_grouped(rng, moe_cfg, moe_params,
                                              calib_x):
    """P=2 mode-grouped layout: the fused pipeline must match both the
    buffer-path kernel and the dense reference on a routing that exercises
    FULL, MAJOR-only, and dropped pairs."""
    prepared, pol, pairs = _two_t_setup(rng, moe_cfg, moe_params, calib_x)
    T = calib_x.shape[0]
    y_buf, ov_buf = moe.moe_forward_dispatch(
        prepared, calib_x, moe_cfg, pairs=pairs, capacity=T,
        use_kernel=True, mode_grouped=True, return_overflow=True)
    y_fus, ov_fus = moe.moe_forward_dispatch(
        prepared, calib_x, moe_cfg, pairs=pairs, capacity=T,
        fused_pipeline=True, mode_grouped=True, return_overflow=True)
    y_ref = moe.moe_forward_ref(prepared, calib_x, moe_cfg, pairs=pairs)
    np.testing.assert_allclose(np.asarray(y_fus), np.asarray(y_buf),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_fus), np.asarray(y_ref),
                               atol=1e-4)
    assert int(ov_buf) == int(ov_fus) == 0


def test_fused_matches_oracle_p1_sub_pairs(rng, moe_cfg, moe_params):
    """P=1 sub-pair layout (no partition): fused pipeline vs the plain
    einsum dispatch path."""
    x = jax.random.normal(jax.random.fold_in(rng, 3),
                          (48, moe_cfg.d_model))
    pairs = NoDrop().route(moe_params, x, moe_cfg)
    cap = moe.capacity_for(48, moe_cfg.top_k, moe_cfg.n_experts, 2.0)
    y_buf = moe.moe_forward_dispatch(moe_params, x, moe_cfg, pairs=pairs,
                                     capacity=cap)
    y_fus = moe.moe_forward_dispatch(moe_params, x, moe_cfg, pairs=pairs,
                                     capacity=cap, fused_pipeline=True)
    np.testing.assert_allclose(np.asarray(y_fus), np.asarray(y_buf),
                               atol=1e-4)


def test_fused_capacity_overflow_consistency(rng, moe_cfg, moe_params,
                                             calib_x):
    """Under real capacity pressure the fused pipeline must drop exactly
    the pairs the buffer path drops — same outputs, same overflow count."""
    prepared, pol, pairs = _two_t_setup(rng, moe_cfg, moe_params, calib_x)
    cap = 8   # << T*K/E: guaranteed overflow for the hot experts
    y_buf, ov_buf = moe.moe_forward_dispatch(
        prepared, calib_x, moe_cfg, pairs=pairs, capacity=cap,
        use_kernel=True, mode_grouped=True, return_overflow=True)
    y_fus, ov_fus = moe.moe_forward_dispatch(
        prepared, calib_x, moe_cfg, pairs=pairs, capacity=cap,
        fused_pipeline=True, mode_grouped=True, return_overflow=True)
    assert int(ov_buf) > 0
    assert int(ov_buf) == int(ov_fus)
    np.testing.assert_allclose(np.asarray(y_fus), np.asarray(y_buf),
                               atol=1e-4)


def test_fused_ragged_f_blocks(rng):
    """f % block_f != 0: the kernel's neuron-axis padding must stay exact
    (padded w1/w3 columns are zero => zero contribution)."""
    E, d, f, T, K = 3, 32, 96, 40, 2
    ks = jax.random.split(rng, 6)
    w1 = jax.random.normal(ks[0], (E, d, f)) * 0.1
    w3 = jax.random.normal(ks[1], (E, d, f)) * 0.1
    w2 = jax.random.normal(ks[2], (E, f, d)) * 0.1
    x = jax.random.normal(ks[3], (T, d))
    group = jax.random.randint(ks[4], (T, K), 0, E)
    wts = jax.random.uniform(ks[5], (T, K))
    cap = 48
    plan = D.sort_dispatch(group, n_groups=E, capacity=cap)
    # oracle: gather -> dense expert FFN -> unpermute + combine
    buf = D.gather_rows(x, plan, cap, index_div=K)
    gathered = D.unpermute(moe.expert_ffn(w1, w3, w2, buf), plan)
    y_ref = (gathered * wts.reshape(-1)[:, None]).reshape(T, K, d).sum(1)
    cf, cm = plan.kernel_counts(cap)
    bc = 16
    tok_s, w_s = D.sorted_pair_arrays(plan, wts, index_div=K, pad=bc)
    y = kops.fused_moe_pipeline(x, w1, w3, w2, plan.group_offsets, cf, cm,
                                tok_s, w_s, capacity=cap,
                                n_minor_start=f, block_c=bc, block_f=64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


def test_fused_empty_experts(rng):
    """Experts that receive zero rows must contribute nothing (their grid
    steps are skipped entirely, incl. the gather/scatter loops)."""
    E, d, f, T = 8, 16, 32, 6
    ks = jax.random.split(rng, 4)
    w1 = jax.random.normal(ks[0], (E, d, f)) * 0.1
    w3 = jax.random.normal(ks[1], (E, d, f)) * 0.1
    w2 = jax.random.normal(ks[2], (E, f, d)) * 0.1
    x = jax.random.normal(ks[3], (T, d))
    group = jnp.zeros((T, 1), jnp.int32)          # everything to expert 0
    wts = jnp.ones((T, 1))
    cap = 8
    plan = D.sort_dispatch(group, n_groups=E, capacity=cap)
    buf = D.gather_rows(x, plan, cap)
    gathered = D.unpermute(moe.expert_ffn(w1, w3, w2, buf), plan)
    y_ref = gathered.reshape(T, 1, d).sum(1)
    cf, cm = plan.kernel_counts(cap)
    tok_s, w_s = D.sorted_pair_arrays(plan, wts, pad=8)
    y = kops.fused_moe_pipeline(x, w1, w3, w2, plan.group_offsets, cf, cm,
                                tok_s, w_s, capacity=cap, n_minor_start=f,
                                block_c=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


# ---------------------------------------------------------------------------
# Bugfix regression: overflow reported in canonical SUB-pair units
# ---------------------------------------------------------------------------

def test_overflow_sub_pair_units_all_paths(rng, moe_cfg, moe_params,
                                           calib_x):
    """The fused (ORIGINAL-expert) kernel paths used to count overflow in
    fused-pair units — under-reporting by up to P-1 sub-pairs per drop vs
    the sub-pair dispatch path. All three paths must now report the SAME
    sub-pair count for the same routing under capacity pressure."""
    from benchmarks.common import sharp_router_params
    params = sharp_router_params(moe_params)
    pol = TwoTDrop(partition_p=2, use_kernel=True)
    prepared, _ = pol.prepare(params, moe_cfg, calib_x)
    # all-FULL routing: every original pair keeps BOTH halves, so any
    # overflow drop on the fused layout hides exactly 2 sub-pairs
    pol = dataclasses.replace(pol, t_major=-1.0, t_minor=-1.0)
    pairs = pol.route(prepared, calib_x, moe_cfg)
    assert bool(pairs.keep.all())
    cap = 8
    _, ov_sub = moe.moe_forward_dispatch(
        prepared, calib_x, moe_cfg, pairs=pairs, capacity=cap,
        return_overflow=True)
    _, ov_krn = moe.moe_forward_dispatch(
        prepared, calib_x, moe_cfg, pairs=pairs, capacity=cap,
        use_kernel=True, mode_grouped=True, return_overflow=True)
    _, ov_fus = moe.moe_forward_dispatch(
        prepared, calib_x, moe_cfg, pairs=pairs, capacity=cap,
        fused_pipeline=True, mode_grouped=True, return_overflow=True)
    assert int(ov_sub) > 0
    assert int(ov_sub) == int(ov_krn) == int(ov_fus)
    # P=2 all-FULL: fused-pair drops are exactly half the sub-pair count,
    # so the OLD (fused-unit) accounting would have reported ov_sub // 2
    assert int(ov_sub) % 2 == 0


def test_overflow_sub_pair_units_mixed_modes(rng, moe_cfg, moe_params,
                                             calib_x):
    """Mixed FULL/MAJOR-only routing: the kernel path's sub-pair overflow
    equals the exact recount from (plan slots x kept halves)."""
    prepared, pol, pairs = _two_t_setup(rng, moe_cfg, moe_params, calib_x)
    cap = 8
    fused = D.fuse_sub_pairs(pairs, 2)
    E = prepared["w1"].shape[0] // 2
    plan = D.sort_dispatch(fused.group, fused.keep, n_groups=E,
                           capacity=cap, major_only=fused.major_only)
    kept_halves = np.asarray(pairs.keep).reshape(
        pairs.keep.shape[0], -1, 2).sum(-1).reshape(-1)
    overflowed = np.asarray(fused.keep).reshape(-1) & \
        (np.asarray(plan.slot).reshape(-1) >= cap)
    expected = int(kept_halves[overflowed].sum())
    _, ov_krn = moe.moe_forward_dispatch(
        prepared, calib_x, moe_cfg, pairs=pairs, capacity=cap,
        use_kernel=True, mode_grouped=True, return_overflow=True)
    assert expected > 0
    assert int(ov_krn) == expected


# ---------------------------------------------------------------------------
# Execution hint: no retrace on threshold change
# ---------------------------------------------------------------------------

def test_fused_pipeline_no_retrace_on_threshold_change(rng, moe_cfg,
                                                       moe_params, calib_x):
    """Thresholds are traced pytree leaves; flipping them under the
    fused_pipeline hint must reuse the jitted computation (the hint itself
    is static aux data and may retrace when IT changes)."""
    prepared, pol, _ = _two_t_setup(rng, moe_cfg, moe_params, calib_x)
    traces = []

    @jax.jit
    def fwd(params, x, policy):
        traces.append(1)
        pairs = policy.route(params, x, moe_cfg)
        return moe.moe_forward_dispatch(
            params, x, moe_cfg, pairs=pairs, capacity=x.shape[0],
            mode_grouped=policy.kernel_mode_grouping,
            fused_pipeline=policy.fused_pipeline)

    x = calib_x[:32]
    fwd(prepared, x, pol)
    assert len(traces) == 1
    moved = dataclasses.replace(pol, t_major=pol.t_major + 0.01,
                                t_minor=pol.t_minor + 0.01)
    fwd(prepared, x, moved)
    assert len(traces) == 1, "threshold change must not retrace"
    off = dataclasses.replace(pol, fused_pipeline=False)
    fwd(prepared, x, off)
    assert len(traces) == 2, "flipping the static hint retraces once"


# ---------------------------------------------------------------------------
# Per-shape dispatch heuristic
# ---------------------------------------------------------------------------

def test_prefer_cumsum_heuristic_table():
    """CPU + few groups + many pairs -> cumsum; everything else -> sort
    (BENCH_dispatch.json: E=8, T>=1024 runs 0.68-0.86x on CPU)."""
    assert D.prefer_cumsum_dispatch(8192, 8, backend="cpu")
    assert D.prefer_cumsum_dispatch(32768, 4, backend="cpu")
    assert not D.prefer_cumsum_dispatch(4096, 8, backend="cpu")
    assert not D.prefer_cumsum_dispatch(8192, 64, backend="cpu")
    assert not D.prefer_cumsum_dispatch(8192, 8, backend="tpu")
    assert not D.prefer_cumsum_dispatch(8192, 8, backend="gpu")


def test_dispatch_plan_heuristic_is_bit_identical(rng):
    """dispatch_plan must produce the SAME plan whichever implementation
    the heuristic picks — on a shape where it picks cumsum."""
    T, K, E = 1024, 8, 8
    ks = jax.random.split(rng, 3)
    group = jax.random.randint(ks[0], (T, K), 0, E)
    keep = jax.random.bernoulli(ks[1], 0.8, (T, K))
    major = jax.random.bernoulli(ks[2], 0.3, (T, K)) & keep
    cap = 1536
    assert D.prefer_cumsum_dispatch(T * K, E, backend="cpu")
    a = D.dispatch_plan(group, keep, n_groups=E, capacity=cap,
                        major_only=major, backend="cpu")
    b = D.sort_dispatch(group, keep, n_groups=E, capacity=cap,
                        major_only=major)
    for name in ("perm", "group_offsets", "counts_full", "counts_major",
                 "group", "slot", "overflow"):
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)
