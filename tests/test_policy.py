"""First-class SparsityPolicy API: registry, equivalence with the dense
reference, pytree/jit behaviour, per-request overrides through the serving
engines, capacity-overflow observability, and the regression pin against
the pre-refactor --dualsparse (route_dualsparse) path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import gating, moe
from repro.core.policy import (POLICIES, LoadAwareTwoT, NoDrop, OneTDrop,
                               PerLayerCalibrated2T, TwoTDrop, make_policy)
from repro.models import model as M


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_complete():
    assert set(POLICIES) == {"none", "1t", "2t", "load_aware", "per_layer"}
    ds = get_config("olmoe-lite").dualsparse
    for name in POLICIES:
        p = make_policy(name, ds)
        assert p.name == name
    with pytest.raises(KeyError):
        make_policy("3t")


# ---------------------------------------------------------------------------
# Equivalence: every policy with thresholds -> keep-all matches the dense
# reference, through the dispatch layer AND the full model
# ---------------------------------------------------------------------------

def _keep_all_policy(name):
    return {
        "none": NoDrop(),
        "1t": OneTDrop(partition_p=2, t_drop=-1.0),
        "2t": TwoTDrop(partition_p=2, t_major=-1.0, t_minor=-1.0),
        "load_aware": LoadAwareTwoT(partition_p=2, t_max=-1.0, t_gap=0.0),
        "per_layer": PerLayerCalibrated2T(partition_p=2, drop_target=0.25),
    }[name]


def _disable_thresholds(name, prepared):
    """per_layer stores thresholds in the params; force them keep-all."""
    if name != "per_layer":
        return prepared
    out = dict(prepared)
    out["thresholds"] = jnp.full_like(prepared["thresholds"], -1.0)
    return out


@pytest.mark.parametrize("name", sorted(POLICIES))
def test_keep_all_matches_dense_reference_dispatch(rng, moe_cfg, moe_params,
                                                   calib_x, name):
    policy = _keep_all_policy(name)
    x = jax.random.normal(rng, (48, moe_cfg.d_model)) * 0.5
    y0 = moe.moe_forward_ref(moe_params, x, moe_cfg)
    prepared, policy = policy.prepare(moe_params, moe_cfg, calib_x)
    prepared = _disable_thresholds(name, prepared)
    pairs = policy.route(prepared, x, moe_cfg)
    y1, overflow = moe.moe_forward_dispatch(prepared, x, moe_cfg,
                                            pairs=pairs, capacity=x.shape[0],
                                            return_overflow=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-4)
    assert int(overflow) == 0


@pytest.mark.parametrize("name", sorted(POLICIES))
def test_keep_all_matches_dense_reference_full_model(rng, moe_cfg, name):
    from repro.data.pipeline import calibration_activations
    from repro.models import transformer as T
    from repro.serving import exact_moe_dist

    policy = dataclasses.replace(_keep_all_policy(name), exact_capacity=True)
    params = M.init_params(rng, moe_cfg)
    calib = calibration_activations(jax.random.fold_in(rng, 5), 128,
                                    moe_cfg.d_model)
    tparams, policy = policy.prepare(params, moe_cfg, calib)
    if name == "per_layer":
        blocks = dict(tparams["blocks"])
        blocks["moe"] = dict(blocks["moe"])
        blocks["moe"]["thresholds"] = jnp.full_like(
            blocks["moe"]["thresholds"], -1.0)
        tparams = {**tparams, "blocks": blocks}
    batch = M.make_batch(rng, moe_cfg, 2, 12, "serve")
    base = T.forward(params, batch, moe_cfg, dist=exact_moe_dist(None))
    dist = dataclasses.replace(exact_moe_dist(None), policy=policy)
    got = T.forward(tparams, batch, moe_cfg, dist=dist)
    np.testing.assert_allclose(np.asarray(base), np.asarray(got),
                               atol=2e-3, rtol=1e-3)


def test_load_aware_uniform_loads_equals_2t(moe_cfg, moe_params, calib_x):
    """§4.3 degenerates to uniform 2T when every device is equally loaded."""
    la = LoadAwareTwoT(partition_p=2, n_devices=4, t_max=0.10, t_gap=0.01)
    two = TwoTDrop(partition_p=2, t_major=0.09, t_minor=0.11)
    prepared, _ = two.prepare(moe_params, moe_cfg, calib_x)
    uniform = jnp.full((4,), 100.0)
    pa = la.route(prepared, calib_x, moe_cfg, loads=uniform)
    pb = two.route(prepared, calib_x, moe_cfg)
    for a, b in zip(pa, pb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Pytree / jit behaviour
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(POLICIES))
def test_policy_pytree_roundtrip(name):
    ds = get_config("olmoe-lite").dualsparse
    p = make_policy(name, ds, use_kernel=False, exact_capacity=True)
    leaves, treedef = jax.tree_util.tree_flatten(p)
    q = jax.tree_util.tree_unflatten(treedef, leaves)
    assert q == p
    assert q.exact_capacity and q.partition_p == p.partition_p


def test_policy_jit_no_retrace_on_threshold_values(moe_cfg, moe_params,
                                                   calib_x):
    """Thresholds are traced leaves: re-entering jit with different VALUES
    of the same policy family must not retrace."""
    two = TwoTDrop(partition_p=2, t_major=-1.0, t_minor=-1.0)
    prepared, _ = two.prepare(moe_params, moe_cfg, calib_x)
    traces = []

    @jax.jit
    def kept(policy, x):
        traces.append(1)
        return policy.route(prepared, x, moe_cfg).keep.sum()

    x = calib_x[:32]
    n_a = int(kept(TwoTDrop(partition_p=2, t_major=0.05, t_minor=0.07), x))
    n_b = int(kept(TwoTDrop(partition_p=2, t_major=0.10, t_minor=0.30), x))
    assert len(traces) == 1
    assert n_a >= n_b                   # higher thresholds keep fewer
    # structural change (different family) retraces — by design
    kept(OneTDrop(partition_p=2, t_drop=0.05), x)
    assert len(traces) == 2


# ---------------------------------------------------------------------------
# Regression pin: the 2t policy IS the pre-refactor --dualsparse path
# ---------------------------------------------------------------------------

def test_2t_policy_routes_identically_to_route_dualsparse(moe_cfg,
                                                          moe_params,
                                                          calib_x):
    """route_dualsparse (the pre-refactor routing entry) and the TwoTDrop
    policy must produce bit-identical pair lists for the config thresholds,
    so --policy 2t reproduces the old --dualsparse tokens exactly."""
    from repro.core import reconstruct
    ds = moe_cfg.dualsparse
    rec = reconstruct.partition_and_reconstruct(moe_params, calib_x, moe_cfg,
                                                p=ds.partition_p)
    pol = make_policy("2t", ds)
    a = pol.route(rec, calib_x, moe_cfg)
    b = moe.route_dualsparse(rec, calib_x, moe_cfg)
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    # and the per-layer side-channel: params["thresholds"] is honoured the
    # same way by the per_layer policy as by route_dualsparse
    rec_th = dict(rec)
    rec_th["thresholds"] = jnp.asarray([0.05, 0.09])
    pl = make_policy("per_layer", ds)
    a = pl.route(rec_th, calib_x, moe_cfg)
    b = moe.route_dualsparse(rec_th, calib_x, moe_cfg)
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


# ---------------------------------------------------------------------------
# Capacity-overflow observability
# ---------------------------------------------------------------------------

def test_overflow_count_exact(rng, moe_cfg, moe_params):
    """Forcing a tiny capacity must report EXACTLY the pairs that could not
    be seated (per-expert kept count minus capacity, positive part)."""
    x = jax.random.normal(rng, (96, moe_cfg.d_model)) * 0.5
    pairs = moe.route_plain(moe_params, x, moe_cfg)
    capacity = 4
    y, overflow = moe.moe_forward_dispatch(moe_params, x, moe_cfg,
                                           pairs=pairs, capacity=capacity,
                                           return_overflow=True)
    hist = np.asarray(gating.expert_histogram(pairs.idx,
                                              moe_cfg.n_experts,
                                              keep=pairs.keep))
    expected = int(np.maximum(hist - capacity, 0).sum())
    assert expected > 0, "test must actually force overflow"
    assert int(overflow) == expected
    assert bool(jnp.isfinite(y).all())
    # ample capacity: zero overflow
    _, none = moe.moe_forward_dispatch(moe_params, x, moe_cfg, pairs=pairs,
                                       capacity=x.shape[0],
                                       return_overflow=True)
    assert int(none) == 0


def test_overflow_surfaces_in_serving_engine(rng, moe_cfg):
    """An engine starved of dispatch capacity must report overflow_pairs>0;
    the exact-capacity continuous default must report exactly 0."""
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import DistContext
    from repro.serving import (ContinuousBatchingEngine, GenerationConfig,
                               ServingEngine)
    params = M.init_params(rng, moe_cfg)
    prompts = [np.asarray((np.arange(24) * m) % moe_cfg.vocab_size)
               for m in (7, 11)]
    gen = GenerationConfig(max_new_tokens=3)

    starved = DistContext(
        mesh=make_host_mesh(1), moe_impl="dispatch",
        policy=NoDrop(capacity_factor=0.01))
    eng = ServingEngine(moe_cfg, params, batch_size=2, max_prompt_len=24,
                        max_new_tokens=3, dist=starved)
    eng.generate(prompts, gen)
    assert eng.overflow_pairs > 0

    cont = ContinuousBatchingEngine(moe_cfg, params, n_slots=2,
                                    max_prompt_len=24, max_new_tokens=3)
    cont.generate(prompts, gen)
    assert cont.overflow_pairs == 0


# ---------------------------------------------------------------------------
# End-to-end: 1T and load-aware through the continuous engine, and
# per-request policy overrides
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["1t", "load_aware"])
def test_policy_end_to_end_continuous_engine(rng, moe_cfg, name):
    """The previously-dead 1T path (and load-aware) now run end to end
    through the continuous-batching engine via the policy registry."""
    from repro.data.pipeline import calibration_activations
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import DistContext
    from repro.serving import ContinuousBatchingEngine, GenerationConfig
    params = M.init_params(rng, moe_cfg)
    calib = calibration_activations(jax.random.fold_in(rng, 9), 128,
                                    moe_cfg.d_model)
    pol = make_policy(name, moe_cfg.dualsparse)
    tparams, pol = pol.prepare(params, moe_cfg, calib)
    dist = DistContext(mesh=make_host_mesh(1), moe_impl="dispatch",
                       policy=pol)
    eng = ContinuousBatchingEngine(moe_cfg, tparams, n_slots=2,
                                   max_prompt_len=12, max_new_tokens=4,
                                   dist=dist)
    prompts = [np.asarray((np.arange(12) * m) % moe_cfg.vocab_size)
               for m in (7, 11, 13)]
    res = eng.generate(prompts, GenerationConfig(max_new_tokens=4))
    assert all(len(r.tokens) == 4 for r in res)
    assert eng.decode_traces == 1 and eng.prefill_traces == 1


def test_per_request_policy_override_isolated(rng, moe_cfg):
    """A request carrying its own thresholds (same family) must produce the
    same tokens co-batched as it does served alone on an engine whose base
    policy equals the override — with zero extra traces."""
    from repro.data.pipeline import calibration_activations
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import DistContext
    from repro.serving import ContinuousBatchingEngine, GenerationConfig
    params = M.init_params(rng, moe_cfg)
    calib = calibration_activations(jax.random.fold_in(rng, 9), 128,
                                    moe_cfg.d_model)
    # NOTE: exact_capacity deliberately NOT set here — the engine's default
    # exact_moe=True installs it on the base policy; a user override built
    # from the ORIGINAL policy (different static hints) must still be
    # accepted, with the engine's hints preserved
    base = TwoTDrop(partition_p=2, t_major=0.07, t_minor=0.09)
    tparams, base = base.prepare(params, moe_cfg, calib)
    override = dataclasses.replace(base, t_major=-1.0, t_minor=-1.0)

    def engine(policy):
        dist = DistContext(mesh=make_host_mesh(1), moe_impl="dispatch",
                           policy=policy)
        return ContinuousBatchingEngine(moe_cfg, tparams, n_slots=3,
                                        max_prompt_len=10, max_new_tokens=5,
                                        dist=dist)

    prompts = [np.asarray((np.arange(10) * m) % moe_cfg.vocab_size)
               for m in (7, 11, 13)]
    gen = GenerationConfig(max_new_tokens=5)
    gen_ov = GenerationConfig(max_new_tokens=5, policy=override)

    eng = engine(base)
    u0 = eng.submit(prompts[0], gen)
    u1 = eng.submit(prompts[1], gen_ov)      # keep-all override, co-batched
    u2 = eng.submit(prompts[2], gen)
    eng.run()
    assert eng.decode_traces == 1            # mixed policies never retrace

    solo_base = engine(base)
    solo_ov = engine(override)
    assert eng.result(u0).tokens == \
        solo_base.generate([prompts[0]], gen)[0].tokens
    assert eng.result(u1).tokens == \
        solo_ov.generate([prompts[1]], gen)[0].tokens
    assert eng.result(u2).tokens == \
        solo_base.generate([prompts[2]], gen)[0].tokens

    # structural mismatch is rejected at submit
    with pytest.raises(ValueError):
        eng.submit(prompts[0], GenerationConfig(
            max_new_tokens=2, policy=OneTDrop(partition_p=2, t_drop=0.1)))


def test_override_preserves_engine_execution_hints(rng, moe_cfg):
    """A per-request override keeps the ENGINE's execution hints: with
    exact_moe the merged policy must still pin capacity (batch invariance),
    even though the user's override object has exact_capacity=False."""
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import DistContext
    from repro.serving import ServingEngine, merge_policy_override
    params = M.init_params(rng, moe_cfg)
    base = TwoTDrop(partition_p=2, t_major=0.07, t_minor=0.09)
    dist = DistContext(mesh=make_host_mesh(1), moe_impl="dispatch",
                       policy=base)
    eng = ServingEngine(moe_cfg, params, batch_size=2, max_prompt_len=8,
                        max_new_tokens=2, dist=dist, exact_moe=True)
    from repro.serving import GenerationConfig
    override = TwoTDrop(partition_p=2, t_major=0.2, t_minor=0.3)
    merged = eng._policy_for(GenerationConfig(policy=override))
    assert merged.exact_capacity            # engine hint survives
    assert float(merged.t_major) == 0.2     # request values win
    with pytest.raises(ValueError):
        merge_policy_override(merged, OneTDrop(t_drop=0.1))
