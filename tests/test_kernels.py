"""Pallas dualsparse FFN kernel vs the pure-jnp oracle, across a
shape/dtype/block sweep (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SWEEP = [
    # (E, C, d, f, block_c, block_f, dtype)
    (4, 64, 128, 256, 32, 64, jnp.float32),
    (2, 100, 96, 160, 32, 32, jnp.float32),     # f/2 not block-aligned
    (3, 128, 128, 256, 128, 128, jnp.bfloat16),
    (1, 7, 64, 96, 8, 16, jnp.float32),         # tiny, padding everywhere
    (8, 33, 64, 128, 16, 64, jnp.float32),      # C not block-aligned
]


def _mk(key, E, C, d, f, dtype):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (E, C, d), dtype) * 0.5
    w1 = jax.random.normal(ks[1], (E, d, f), dtype) * 0.1
    w3 = jax.random.normal(ks[2], (E, d, f), dtype) * 0.1
    w2 = jax.random.normal(ks[3], (E, f, d), dtype) * 0.1
    cf = jax.random.randint(ks[4], (E,), 0, C // 2 + 1)
    cm = jax.random.randint(ks[5], (E,), 0, C // 2 + 1)
    return x, w1, w3, w2, cf, cm


@pytest.mark.parametrize("E,C,d,f,bc,bf,dtype", SWEEP)
def test_kernel_matches_oracle(rng, E, C, d, f, bc, bf, dtype):
    x, w1, w3, w2, cf, cm = _mk(rng, E, C, d, f, dtype)
    got = ops.grouped_swiglu(x, w1, w3, w2, cf, cm, block_c=bc, block_f=bf)
    want = ref.grouped_swiglu_ref(x, w1, w3, w2, cf, cm)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("E,C,d,f,bc,bf,dtype", SWEEP[:3])
def test_kernel_full_counts(rng, E, C, d, f, bc, bf, dtype):
    x, w1, w3, w2, _, _ = _mk(rng, E, C, d, f, dtype)
    got = ops.grouped_swiglu(x, w1, w3, w2, block_c=bc, block_f=bf)
    want = ref.grouped_swiglu_ref(x, w1, w3, w2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_kernel_zero_counts_zero_output(rng):
    x, w1, w3, w2, _, _ = _mk(rng, 2, 32, 64, 128, jnp.float32)
    z = jnp.zeros((2,), jnp.int32)
    got = ops.grouped_swiglu(x, w1, w3, w2, z, z)
    assert float(jnp.abs(got).max()) == 0.0


def test_kernel_major_half_only(rng):
    """counts_major rows use ONLY the first f/2 neurons."""
    E, C, d, f = 2, 16, 64, 128
    x, w1, w3, w2, _, _ = _mk(rng, E, C, d, f, jnp.float32)
    cf = jnp.zeros((E,), jnp.int32)
    cm = jnp.full((E,), C, jnp.int32)
    got = ops.grouped_swiglu(x, w1, w3, w2, cf, cm)
    # oracle: zero out minor neurons entirely
    w1m = w1.at[:, :, f // 2:].set(0.0)
    w3m = w3.at[:, :, f // 2:].set(0.0)
    want = ref.grouped_swiglu_ref(x, w1m, w3m, w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# SSD intra-chunk kernel (kernels/ssd_chunk.py)
# ---------------------------------------------------------------------------

from repro.kernels.ssd_chunk import ssd_chunk_pallas, ssd_chunk_ref
from repro.models import mamba2 as mm


@pytest.mark.parametrize("BH,nc,Q,P,N", [(3, 4, 32, 16, 8),
                                         (2, 2, 128, 64, 128),
                                         (1, 5, 16, 8, 8)])
def test_ssd_chunk_kernel_matches_oracle(rng, BH, nc, Q, P, N):
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (BH, nc, Q, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, nc, Q)))
    a = -jnp.exp(jax.random.normal(ks[2], (BH,)) * 0.5)
    bm = jax.random.normal(ks[3], (BH, nc, Q, N))
    cm = jax.random.normal(ks[4], (BH, nc, Q, N))
    y1, s1, d1 = ssd_chunk_pallas(x, dt, a, bm, cm)
    y2, s2, d2 = ssd_chunk_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-6)


def test_ssd_kernel_full_path_matches_sequential(rng):
    ks = jax.random.split(rng, 5)
    b, S, H, P, G, N = 2, 100, 4, 16, 2, 8
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (b, S, G, N))
    C = jax.random.normal(ks[4], (b, S, G, N))
    y1, h1 = mm.ssd_chunked_kernel(x, dt, A, B, C, chunk=32)
    y2, h2 = mm.ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)
