"""repro.obs: traced on-device metrics, span tracing, and the export layer.

Covers the three contracts the observability seam must keep:
  * outputs are BIT-IDENTICAL with metrics on vs off (dispatch, fused
    pipeline, and S-ETP paths; engine greedy tokens);
  * counter-value changes never retrace the jitted decode step;
  * the export surface round-trips (Prometheus text, Chrome-trace JSON)
    and the legacy ``cache["moe_overflow"]`` read warns but still works.
"""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import make_policy
from repro.models import model as M
from repro.models.transformer import DistContext
from repro.obs import (MetricsState, ObsCache, MetricsSnapshot,
                       SpanTracer, metrics_spec, parse_prometheus,
                       render_prometheus)
from repro.serving import (ContinuousBatchingEngine, GenerationConfig,
                           PagedEngine, Request, ServingEngine)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("mixtral-8x7b-lite")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, mults=(7, 11, 13, 17, 5)):
    return [np.asarray((np.arange(L) * m) % cfg.vocab_size)
            for L, m in zip(lens, mults)]


# ---------------------------------------------------------------------------
# MetricsState / ObsCache pytree mechanics
# ---------------------------------------------------------------------------

def test_metrics_state_pytree_roundtrip():
    s = MetricsState.zeros(3, 8)
    leaves, treedef = jax.tree_util.tree_flatten(s)
    assert len(leaves) == 5
    s2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(s2, MetricsState)
    assert s2.expert_load.shape == (3, 8)
    total = s + s2
    assert int(total.total_pairs) == 0


def test_obs_cache_is_registered_pytree():
    c = ObsCache({"b": jnp.ones(2), "a": jnp.zeros(3)})
    leaves, treedef = jax.tree_util.tree_flatten(c)
    c2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(c2, ObsCache)
    assert sorted(c2) == ["a", "b"]
    # treedef must be stable across rebuilds — retrace hazard otherwise
    assert jax.tree_util.tree_structure(c2) == treedef


def test_metrics_spec_shapes(served):
    cfg, params = served
    spec = metrics_spec(cfg, params)
    assert spec is not None
    n_layers, n_sub = spec
    assert n_layers == cfg.n_layers
    # NoDrop default: no partition, sub-experts == experts
    assert n_sub == cfg.n_experts
    dense = get_config("qwen2-7b").reduced()
    assert metrics_spec(dense, {}) is None


# ---------------------------------------------------------------------------
# Bit-identity + counter consistency on the model paths
# ---------------------------------------------------------------------------

def _dispatch_dist(cfg, *, fused=False):
    from repro.launch.mesh import make_host_mesh
    policy = make_policy("2t", cfg.dualsparse, use_kernel=not fused,
                         fused_pipeline=fused)
    return policy, DistContext(mesh=make_host_mesh(1), moe_impl="dispatch",
                               policy=policy)


def test_prefill_bit_identical_and_counters_consistent(served):
    cfg, params = served
    batch = {"tokens": jnp.asarray(_prompts(cfg, [12])[0])[None, :]}
    on = M.make_prefill_step(cfg, cache_len=16, metrics=True)
    off = M.make_prefill_step(cfg, cache_len=16, metrics=False)
    logits_on, cache_on = on(params, batch)
    logits_off, cache_off = off(params, batch)
    assert jnp.array_equal(logits_on, logits_off)
    m = cache_on["metrics"]
    assert isinstance(m, MetricsState)
    assert "metrics" not in cache_off and "moe_overflow" in cache_off
    # every routed pair is kept, dropped, or was never kept (NoDrop: all
    # kept as FULL, nothing dropped); histogram counts kept pairs only
    T = batch["tokens"].shape[1]
    total = T * cfg.top_k * cfg.n_layers
    assert int(m.total_pairs) == total
    assert int(m.dropped_pairs) == 0 and int(m.kept_major) == 0
    assert int(m.expert_load.sum()) == int(m.kept_full + m.kept_major)
    assert m.expert_load.shape == (cfg.n_layers, cfg.n_experts)


def test_policy_paths_bit_identical_with_metrics(served):
    """2T-Drop via the dispatch path and the fused Pallas pipeline: the
    collect branch must not perturb the forward value."""
    cfg, params = served
    x = jnp.asarray(_prompts(cfg, [10])[0])[None, :]
    for fused in (False, True):
        policy, dist = _dispatch_dist(cfg, fused=fused)
        outs = {}
        for metrics in (True, False):
            step = M.make_prefill_step(cfg, cache_len=12, dist=dist,
                                       metrics=metrics)
            logits, cache = step(params, {"tokens": x})
            outs[metrics] = logits
        assert jnp.array_equal(outs[True], outs[False]), f"fused={fused}"


def test_setp_stats_match_overflow_path(moe_cfg, moe_params, calib_x):
    """S-ETP with return_stats: y bit-identical to the overflow-only call,
    stats internally consistent, overflow scalar equal on both calls."""
    from repro.core.setp import setp_moe_forward
    from repro.launch.mesh import make_host_mesh
    cfg = moe_cfg
    policy = make_policy("2t", cfg.dualsparse)
    params, policy = policy.prepare(moe_params, cfg, calib_x)
    mesh = make_host_mesh(1)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model))
    y_ref, overflow = setp_moe_forward(params, x, cfg, mesh, policy=policy,
                                       return_overflow=True)
    y, stats = setp_moe_forward(params, x, cfg, mesh, policy=policy,
                                return_stats=True)
    assert jnp.array_equal(y, y_ref)
    assert int(stats["overflow_pairs"]) == int(overflow)
    T = x.shape[0] * x.shape[1]
    P = policy.partition_p
    kept = int(stats["kept_full"] + stats["kept_major"])
    assert kept + int(stats["dropped_pairs"]) == T * cfg.top_k * P
    assert int(stats["expert_load"].sum()) == kept


# ---------------------------------------------------------------------------
# Engines: identity, accumulation, no-retrace, migration
# ---------------------------------------------------------------------------

def test_engines_bit_identical_with_metrics(served):
    cfg, params = served
    prompts = _prompts(cfg, [6, 10, 8])
    gen = GenerationConfig(max_new_tokens=5)
    tokens = {}
    for metrics in (True, False):
        eng = ContinuousBatchingEngine(cfg, params, n_slots=2,
                                       max_prompt_len=12, max_new_tokens=6,
                                       cache_dtype=jnp.float32,
                                       metrics=metrics)
        tokens[metrics] = [r.tokens for r in eng.generate(prompts, gen)]
    assert tokens[True] == tokens[False]


def test_decode_never_retraces_on_counter_values(served):
    """The structural gate: metric VALUES change every step; the cache
    treedef (including the ObsCache wrapper and MetricsState leaves) must
    not, so the decode executable is hit exactly once."""
    cfg, params = served
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2,
                                   max_prompt_len=12, max_new_tokens=16,
                                   metrics=True)
    for p in _prompts(cfg, [6, 10, 8, 5]):
        eng.submit(Request(prompt=p, gen=GenerationConfig(max_new_tokens=12)))
    before = None
    while eng.step():
        if before is None:
            before = int(eng._cache["metrics"].total_pairs)
    after = int(eng._cache["metrics"].total_pairs)
    assert after > before          # counters really accumulated
    assert eng.decode_traces == 1
    assert eng.prefill_traces == 1


def test_overflow_pairs_migration_and_deprecation(served):
    cfg, params = served
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2,
                                   max_prompt_len=12, max_new_tokens=6,
                                   metrics=True)
    eng.generate(_prompts(cfg, [6, 8]), GenerationConfig(max_new_tokens=3))
    assert eng.overflow_pairs == int(eng._cache["metrics"].overflow_pairs)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = eng._cache["moe_overflow"]
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert int(legacy) == eng.overflow_pairs
    # metrics=False keeps the legacy scalar, no warning
    eng2 = ContinuousBatchingEngine(cfg, params, n_slots=2,
                                    max_prompt_len=12, max_new_tokens=6,
                                    metrics=False)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert int(eng2._cache["moe_overflow"]) == 0


def test_paged_engine_metrics_and_page_gauges(served):
    cfg, params = served
    eng = PagedEngine(cfg, params, n_slots=2, page_size=4, chunk_size=8,
                      max_prompt_len=12, max_new_tokens=6, metrics=True)
    eng.generate(_prompts(cfg, [9, 9, 6]), GenerationConfig(max_new_tokens=4))
    snap = eng.metrics()
    states = {s: snap.gauges[f'repro_page_pool_pages{{state="{s}"}}']
              for s in ("free", "held", "parked")}
    assert sum(states.values()) == eng.n_pages - 1
    assert snap.counters['repro_prefix_cache_total{event="hit"}'] \
        == eng.prefix_hits
    assert eng.chunk_traces == 1 and eng.decode_traces == 1


def test_engine_timing_and_request_latency(served):
    cfg, params = served
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2,
                                   max_prompt_len=12, max_new_tokens=8,
                                   metrics=True)
    res = eng.generate(_prompts(cfg, [6, 10, 8]),
                       GenerationConfig(max_new_tokens=6))
    t = eng.timing
    assert t["compile_steps"] >= 1 and t["steady_steps"] >= 1
    assert t["compile_s"] > t["steady_step_s"] > 0
    for r in res:
        assert r.ttft_s is not None and r.tpot_s is not None
        assert 0 < r.ttft_s <= r.latency_s
    snap = eng.metrics()
    h = snap.histograms["repro_request_ttft_seconds"]
    assert h.count == len(res)


# ---------------------------------------------------------------------------
# Span tracer / Chrome trace
# ---------------------------------------------------------------------------

def test_chrome_trace_valid_json_with_nested_spans(tmp_path):
    tr = SpanTracer()
    with tr.span("outer", kind="test"):
        with tr.span("inner"):
            tr.instant("tick", n=1)
    path = tmp_path / "trace.json"
    tr.write_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    by_name = {e["name"]: e for e in evs}
    assert by_name["tick"]["ph"] == "i"
    outer, inner = by_name["outer"], by_name["inner"]
    assert outer["ph"] == inner["ph"] == "X"
    # inner nests fully within outer on the timeline
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert outer["args"]["kind"] == "test"


def test_disabled_tracer_records_nothing(served):
    cfg, params = served
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2,
                                   max_prompt_len=12, max_new_tokens=6,
                                   metrics=False)
    eng.generate(_prompts(cfg, [6, 8]), GenerationConfig(max_new_tokens=3))
    assert eng.tracer.events() == []


# ---------------------------------------------------------------------------
# Export: Prometheus exposition + JSON lines + schema validator
# ---------------------------------------------------------------------------

def test_prometheus_round_trip():
    snap = MetricsSnapshot()
    snap.counter("repro_moe_subpairs_total", 42, outcome="kept_full")
    snap.counter("repro_moe_subpairs_total", 7, outcome="dropped")
    snap.gauge("repro_queue_depth", 3)
    snap.histogram("repro_request_latency_seconds", [0.002, 0.3, 0.3, 12.0])
    text = render_prometheus(snap)
    back = parse_prometheus(text)
    assert back.counters == snap.counters
    assert back.gauges == snap.gauges
    h0 = snap.histograms["repro_request_latency_seconds"]
    h1 = back.histograms["repro_request_latency_seconds"]
    assert h0.counts == h1.counts and h0.sum == pytest.approx(h1.sum)
    # render is deterministic and self-consistent
    assert render_prometheus(back) == text


def test_metrics_server_scrape(served):
    import urllib.request
    cfg, params = served
    eng = ServingEngine(cfg, params, metrics=True)
    eng.generate(_prompts(cfg, [6]), GenerationConfig(max_new_tokens=3))
    from repro.obs import MetricsServer
    srv = MetricsServer(eng.metrics, port=0).start()
    try:
        with urllib.request.urlopen(srv.url) as resp:
            assert resp.status == 200
            text = resp.read().decode()
    finally:
        srv.stop()
    snap = parse_prometheus(text)
    assert snap.counters['repro_requests_total{state="finished"}'] == 1


def test_obs_bench_schema_validator():
    from repro.lint.bench_schema import validate_obs_bench
    good = {
        "bench": "obs_overhead", "unit": "us_per_decode_step", "note": "x",
        "runs": [{
            "timestamp": "2026-01-01T00:00:00Z",
            "host": {"backend": "cpu", "devices": 1},
            "smoke": False,
            "rows": [{"engine": "continuous", "decode_steps": 10,
                      "decode_us_on": 100.0, "decode_us_off": 98.0,
                      "tok_s_on": 40.0, "tok_s_off": 41.0,
                      "overhead_frac": 0.02}],
        }],
    }
    assert validate_obs_bench(good) == []
    bad = json.loads(json.dumps(good))
    del bad["runs"][0]["rows"][0]["overhead_frac"]
    bad["runs"][0]["rows"].append({"engine": "x", "decode_steps": 1,
                                  "decode_us_on": 1, "decode_us_off": 1,
                                  "tok_s_on": 1, "tok_s_off": 1,
                                  "overhead_frac": 99.0})
    errs = validate_obs_bench(bad)
    assert any("missing key 'overhead_frac'" in e for e in errs)
    assert any("credible" in e for e in errs)


def test_serving_engine_row_schema_requires_timing():
    from repro.lint.bench_schema import SERVING_ENGINE_ROW, _check_keys
    row = {"engine": "paged", "requests": 4, "tokens": 16,
           "throughput_tok_s": 10.0, "wall_s": 1.6}
    errs = _check_keys(row, SERVING_ENGINE_ROW, "engines[0]")
    assert any("compile_s" in e for e in errs)
    assert any("steady_step_s" in e for e in errs)
