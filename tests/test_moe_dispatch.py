"""Capacity dispatch path vs the dense reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import drop, gating, moe, reconstruct


def test_dispatch_matches_ref(rng, moe_cfg, moe_params):
    x = jax.random.normal(rng, (64, moe_cfg.d_model)) * 0.5
    y0 = moe.moe_forward_ref(moe_params, x, moe_cfg)
    y1 = moe.moe_forward_dispatch(moe_params, x, moe_cfg,
                                  capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)


def test_dispatch_with_kernel_matches_ref(rng, moe_cfg, moe_params):
    x = jax.random.normal(rng, (64, moe_cfg.d_model)) * 0.5
    y0 = moe.moe_forward_ref(moe_params, x, moe_cfg)
    y1 = moe.moe_forward_dispatch(moe_params, x, moe_cfg,
                                  capacity_factor=8.0, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-4)


def test_dispatch_dualsparse_pairs(rng, moe_cfg, moe_params, calib_x):
    rec = reconstruct.partition_and_reconstruct(moe_params, calib_x, moe_cfg,
                                                p=2)
    rec["wg"] = moe_params["wg"]
    x = calib_x[:48]
    pairs = moe.route_dualsparse(rec, x, moe_cfg,
                                 thresholds=(0.09, 0.11))
    y_ref = moe.moe_forward_ref(rec, x, moe_cfg, pairs=pairs)
    y_dis = moe.moe_forward_dispatch(rec, x, moe_cfg, pairs=pairs,
                                     capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_dis),
                               atol=1e-5)


def test_capacity_overflow_drops_gracefully(rng, moe_cfg, moe_params):
    """Over-capacity pairs are dropped, not mis-routed: output stays finite
    and close to reference in RMS."""
    x = jax.random.normal(rng, (128, moe_cfg.d_model)) * 0.5
    y = moe.moe_forward_dispatch(moe_params, x, moe_cfg,
                                 capacity_factor=0.5)
    assert bool(jnp.isfinite(y).all())


def test_shared_expert_path(rng):
    from repro.configs import get_config
    cfg = get_config("dsv2-lite-lite")
    from repro.models.layers import split_params
    params, _ = split_params(moe.make_moe_params(rng, cfg))
    assert "shared" in params
    x = jax.random.normal(rng, (32, cfg.d_model)) * 0.5
    y0 = moe.moe_forward_ref(params, x, cfg)
    y1 = moe.moe_forward_dispatch(params, x, cfg, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)
    # shared experts contribute even when routed experts are all dropped
    r = gating.route(x, params["wg"], cfg.top_k, cfg.router_norm_topk)
    pairs = drop.expand_pairs_1t(r.idx, r.combine, r.norm_score, 1, 2.0)
    y_dropped = moe.moe_forward_ref(params, x, cfg, pairs=pairs)
    assert float(jnp.abs(y_dropped).max()) > 0.0
