"""repro.lint: each pass family must catch its seeded violation, and the
repo as landed must come out clean on the fast entry set."""
import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import SparsityPolicy, register_policy, POLICIES
from repro.kernels import (fused_moe_pipeline_kernel_spec,
                           grouped_swiglu_kernel_spec)
from repro.lint import Baseline, Finding, Severity, build_entries, run_lint
from repro.lint import bench_schema, hlo_passes, jaxpr_passes, pallas_passes

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# jaxpr family
# ---------------------------------------------------------------------------

def test_dtype_pass_catches_injected_f64():
    def bad(x):
        return jnp.cumsum(x.astype(jnp.float64))   # seeded upcast

    with jax.experimental.enable_x64():
        jaxpr = jax.make_jaxpr(bad)(
            jax.ShapeDtypeStruct((8,), jnp.float32))
    found = jaxpr_passes.check_dtype_promotion(jaxpr, "seeded")
    assert any(f.severity == Severity.ERROR and f.pass_name == "jaxpr-dtype"
               for f in found), found


def test_dtype_pass_catches_weak_type_promotion():
    """The pre-fix load_aware.py shape: dividing an integer histogram
    without an explicit f32 cast promotes to f64 under x64 — exactly what
    the f32 pinning in core.load_aware now prevents."""
    def leaky(scores):
        hist = jnp.arange(scores.shape[0])
        return hist / hist.size                    # i64/int -> f64 on x64

    with jax.experimental.enable_x64():
        jaxpr = jax.make_jaxpr(leaky)(
            jax.ShapeDtypeStruct((32,), jnp.float32))
    found = jaxpr_passes.check_dtype_promotion(jaxpr, "seeded")
    assert found, "weak-type promotion went undetected"


def test_calibration_entries_clean_under_x64():
    """core.drop / core.load_aware calibration math is f32-explicit: the
    x64 probe entries produce zero dtype findings (the satellite fix)."""
    entries = [e for e in build_entries(include_hlo=False,
                                        include_engine=False)
               if e.name.startswith("calib/")]
    assert len(entries) == 2
    for e in entries:
        art = e.trace()
        assert jaxpr_passes.check_dtype_promotion(art.jaxpr, e.name) == []


def test_host_sync_pass_catches_debug_print():
    def chatty(x):
        jax.debug.print("x={x}", x=x)
        return x * 2

    jaxpr = jax.make_jaxpr(chatty)(jax.ShapeDtypeStruct((4,), jnp.float32))
    found = jaxpr_passes.check_host_sync(jaxpr, "seeded")
    assert any(f.pass_name == "jaxpr-hostsync" for f in found)


def test_host_sync_pass_catches_pure_callback():
    def roundtrip(x):
        return jax.pure_callback(
            lambda a: np.asarray(a) * 2, jax.ShapeDtypeStruct((4,),
                                                              np.float32), x)

    jaxpr = jax.make_jaxpr(roundtrip)(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    found = jaxpr_passes.check_host_sync(jaxpr, "seeded")
    assert any(f.severity == Severity.ERROR for f in found)


def test_traced_leaves_pass_accepts_argument_and_flags_constant():
    """The page-table retrace-hazard check: an int32 indirection array
    passed as an argument is clean; the same array captured as a closure
    constant (whose VALUE would hash into the jit cache key) is an ERROR."""
    table = jnp.zeros((2, 5), jnp.int32)
    spec = [[(2, 5), "int32"]]

    def good(x, pt):
        return jnp.take(x, pt.reshape(-1), axis=0)

    jaxpr = jax.make_jaxpr(good)(jax.ShapeDtypeStruct((8, 4), jnp.float32),
                                 jax.ShapeDtypeStruct((2, 5), jnp.int32))
    assert jaxpr_passes.check_traced_leaves(jaxpr, "seeded", spec) == []

    def bad(x):
        return jnp.take(x, table.reshape(-1), axis=0)   # captured constant

    jaxpr = jax.make_jaxpr(bad)(jax.ShapeDtypeStruct((8, 4), jnp.float32))
    found = jaxpr_passes.check_traced_leaves(jaxpr, "seeded", spec)
    assert any(f.fingerprint ==
               "jaxpr-traced-leaves:leaf-captured-constant:seeded"
               for f in found), found

    missing = jaxpr_passes.check_traced_leaves(jaxpr, "seeded",
                                               [[(3, 7), "int32"]])
    assert any(f.pass_name == "jaxpr-traced-leaves"
               and "leaf-missing" in f.fingerprint for f in missing)


def test_paged_engine_entries_trace_clean():
    """The paged serving steps take the page table as a traced invar (no
    captured constants) and carry the traced_leaves meta the runner keys
    the check on."""
    entries = [e for e in build_entries(include_hlo=False)
               if e.name in ("engine/chunk_insert", "engine/paged_decode",
                             "engine/prefix_hit_insert")]
    assert len(entries) == 3
    for e in entries:
        assert e.meta.get("traced_leaves")
        art = e.trace()
        assert jaxpr_passes.check_traced_leaves(
            art.jaxpr, e.name, e.meta["traced_leaves"]) == []


# ---------------------------------------------------------------------------
# policy retrace-hazard family
# ---------------------------------------------------------------------------

def _register_throwaway(cls, name):
    register_policy(name)(cls)
    POLICIES.pop(name, None)           # keep the production registry clean
    return cls


def test_retrace_pass_flags_unhashable_static():
    @dataclasses.dataclass(frozen=True)
    class ListStatic(SparsityPolicy):
        knobs: Tuple = dataclasses.field(default_factory=lambda: [1, 2])
        _dynamic: Tuple[str, ...] = ()

        @classmethod
        def from_config(cls, ds, drop_target=None, **kw):
            return cls(**kw)

    _register_throwaway(ListStatic, "__lint_unhashable")
    found = jaxpr_passes.check_policy_retrace({"bad": ListStatic})
    assert any(f.code == "unhashable-static" for f in found), found


def test_retrace_pass_flags_array_valued_static():
    @dataclasses.dataclass(frozen=True)
    class ArrayStatic(SparsityPolicy):
        table: Tuple = dataclasses.field(
            default_factory=lambda: np.zeros(3))
        _dynamic: Tuple[str, ...] = ()      # table SHOULD be dynamic

        @classmethod
        def from_config(cls, ds, drop_target=None, **kw):
            return cls(**kw)

    _register_throwaway(ArrayStatic, "__lint_arraystatic")
    found = jaxpr_passes.check_policy_retrace({"bad": ArrayStatic})
    assert any(f.code == "traced-value-hashed" for f in found), found


def test_retrace_pass_flags_phantom_dynamic_field():
    @dataclasses.dataclass(frozen=True)
    class Phantom(SparsityPolicy):
        _dynamic: Tuple[str, ...] = ("no_such_field",)

        @classmethod
        def from_config(cls, ds, drop_target=None, **kw):
            return cls(**kw)

    # NOT registered: register_policy would raise on flatten; the pass must
    # diagnose rather than crash
    found = jaxpr_passes.check_policy_retrace({"bad": Phantom})
    assert any(f.code == "dynamic-not-a-field" for f in found), found


def test_retrace_pass_clean_on_production_registry():
    assert jaxpr_passes.check_policy_retrace() == []


# ---------------------------------------------------------------------------
# HLO family
# ---------------------------------------------------------------------------

def test_capacity_buffer_pass_catches_injected_materialization():
    E, cap, d = 4, 64, 32

    def leaky(x):
        buf = jnp.broadcast_to(x[None, None, :], (E, cap, d)) * 2.0
        return buf.sum()

    hlo = jax.jit(leaky).lower(
        jax.ShapeDtypeStruct((d,), jnp.float32)).compile().as_text()
    found = hlo_passes.check_forbidden_shapes(hlo, "seeded", [(E, cap, d)])
    assert any(f.code == "forbidden-shape" and
               f.severity == Severity.ERROR for f in found), found
    # and the converse guard sees it too
    assert hlo_passes.check_required_shapes(hlo, "seeded",
                                            [(E, cap, d)]) == []
    assert hlo_passes.check_required_shapes(hlo, "seeded",
                                            [(E, cap + 1, d)]) != []


def test_capacity_buffer_count_matches_bench_semantics():
    """capacity_buffer_count (the helper bench_moe_pipeline now imports)
    counts both the exact and the block-padded capacity layouts."""
    E, cap, d = 2, 200, 16

    def f(x):
        a = jnp.broadcast_to(x, (E, cap, d)) * 1.5
        b = jnp.broadcast_to(x, (E, 256, d)) + 1.0   # padded-to-128 layout
        return a.sum() + b.sum()

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((d,), jnp.float32)).compile().as_text()
    n_both = hlo_passes.capacity_buffer_count(hlo, E, cap, d, block_c=128)
    n_exact = hlo_passes.capacity_buffer_count(hlo, E, cap, d, block_c=cap)
    assert n_both > n_exact > 0


_SYNTH_A2A = """\
ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %p = f32[8,16] parameter(0)
  %a = f32[8,16] all-to-all(%p), dimensions={0}
  %b = f32[8,16] all-to-all(%a), dimensions={0}
  %c = f32[8,16] all-to-all(%b), dimensions={0}
  %g = f32[8,16] all-gather(%c), dimensions={0}
  ROOT %r = f32[8,16] add(%g, %p)
}
"""


def test_collective_budget_pass():
    found = hlo_passes.check_collective_budget(
        _SYNTH_A2A, "seeded", {"all-to-all": 2, "all-gather": 0})
    codes = {f.code for f in found}
    assert codes == {"budget-all-to-all", "budget-all-gather"}, found
    assert hlo_passes.check_collective_budget(
        _SYNTH_A2A, "seeded", {"all-to-all": 3, "all-gather": 1}) == []


def test_hbm_bytes_regression_gate():
    def f(x):
        return (x @ x.T).sum()

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile().as_text()
    from repro.launch.hlo_analysis import analyze_hlo
    actual = analyze_hlo(hlo).hbm_bytes
    assert hlo_passes.check_hbm_bytes(hlo, "e", actual) == []
    assert any(f.code == "no-baseline"
               for f in hlo_passes.check_hbm_bytes(hlo, "e", None))
    regress = hlo_passes.check_hbm_bytes(hlo, "e", actual / 10)
    assert any(f.code == "regression" and f.severity == Severity.ERROR
               for f in regress)


# ---------------------------------------------------------------------------
# Pallas family
# ---------------------------------------------------------------------------

def test_vmem_pass_streamed_prefill_passes_unstreamed_fails():
    """The satellite-1 regression pair: at prefill scale (T=8192) the
    STREAMED spec (pair maps in SMEM, x/out in ANY memory behind DMA)
    fits the 16 MB budget, while the deliberately unstreamed (resident)
    layout still blows it — deleting the old prod_prefill suppression
    must never silently re-admit a resident prefill kernel."""
    kw = dict(capacity=2048, dtype=jnp.bfloat16, p_factor=2)
    T, n_pairs = 8192, 8192 * 8 + 128
    ok = fused_moe_pipeline_kernel_spec(T, 2048, 384, 128, n_pairs,
                                        streamed=True, **kw)
    assert pallas_passes.check_vmem_footprint(ok, "streamed") == []
    # acceptance shape: wide model (d=4096, E=64) at T=8192 also fits
    wide = fused_moe_pipeline_kernel_spec(T, 4096, 7168, 64, 8192 * 2 + 128,
                                          streamed=True, capacity=1024,
                                          dtype=jnp.bfloat16, p_factor=2)
    assert pallas_passes.check_vmem_footprint(wide, "streamed-wide") == []
    bad = fused_moe_pipeline_kernel_spec(T, 2048, 384, 128, n_pairs,
                                         streamed=False, **kw)
    found = pallas_passes.check_vmem_footprint(bad, "resident")
    assert any(f.code == "vmem-budget" and f.severity == Severity.ERROR
               for f in found), found


def test_vmem_pass_passes_decode_scale():
    for streamed in (True, False):
        spec = fused_moe_pipeline_kernel_spec(
            256, 2048, 384, 128, 256 * 16 + 128, capacity=64,
            dtype=jnp.bfloat16, p_factor=2, streamed=streamed)
        assert pallas_passes.check_vmem_footprint(spec, "ok") == []


def test_smem_pass_budget_and_clean():
    # mode-grouped prefill maps fit SMEM
    ok = fused_moe_pipeline_kernel_spec(
        8192, 2048, 384, 128, 8192 * 8 + 128, capacity=2048,
        dtype=jnp.bfloat16, p_factor=2)
    assert pallas_passes.check_smem_footprint(ok, "ok") == []
    # a raw sub-pair layout at prefill scale (T*top_k*P entries) does not
    big = fused_moe_pipeline_kernel_spec(
        16384, 2048, 384, 128, 16384 * 8 * 2 + 128, capacity=4096,
        dtype=jnp.bfloat16, p_factor=2)
    found = pallas_passes.check_smem_footprint(big, "seeded")
    assert any(f.code == "smem-budget" and f.severity == Severity.ERROR
               for f in found), found
    # the resident layout keeps maps in VMEM: nothing for this pass
    res = fused_moe_pipeline_kernel_spec(
        64, 2048, 384, 128, 64 * 16 + 128, capacity=64,
        dtype=jnp.bfloat16, p_factor=2, streamed=False)
    assert res.smem_bytes() == 0
    assert pallas_passes.check_smem_footprint(res, "resident") == []


def test_dma_pass_requires_staged_double_buffering():
    spec = fused_moe_pipeline_kernel_spec(
        256, 2048, 384, 128, 256 * 16 + 128, capacity=64,
        dtype=jnp.bfloat16, p_factor=2)
    assert pallas_passes.check_dma_streaming(spec, "ok") == []
    tampered = dataclasses.replace(spec, blocks=tuple(
        dataclasses.replace(b, dma_buffers=1) if b.name == "x" else b
        for b in spec.blocks))
    found = pallas_passes.check_dma_streaming(tampered, "seeded")
    assert any(f.code == "single-buffered-input" for f in found), found
    dead = dataclasses.replace(spec, blocks=tuple(
        dataclasses.replace(b, dma_buffers=0) if b.name == "out" else b
        for b in spec.blocks))
    found = pallas_passes.check_dma_streaming(dead, "seeded")
    assert any(f.code == "any-unreachable" and
               f.severity == Severity.ERROR for f in found), found


def test_mxu_pass_catches_misaligned_block():
    spec = grouped_swiglu_kernel_spec(4, 256, 256, 512, block_f=100)
    found = pallas_passes.check_mxu_alignment(spec, "seeded")
    assert any(f.code == "lane-misaligned" and
               f.severity == Severity.ERROR for f in found), found


def test_mxu_pass_full_axis_block_is_info_not_error():
    """olmoe-lite reduced: f/P = 64 < 128 lanes — the block spans the full
    axis, so the hardware pads it; must NOT be a CI-failing ERROR."""
    spec = grouped_swiglu_kernel_spec(8, 64, 256, 64, p_factor=1)
    found = pallas_passes.check_mxu_alignment(spec, "reduced")
    assert all(f.severity == Severity.INFO for f in found), found


def test_grid_pass_clean_on_real_specs_and_catches_tamper():
    spec = grouped_swiglu_kernel_spec(8, 200, 256, 96, p_factor=2)
    assert pallas_passes.check_grid_coverage(spec, "ok") == []
    bad = dataclasses.replace(spec, grid=(8, 1, spec.grid[2]))
    found = pallas_passes.check_grid_coverage(bad, "seeded")
    assert any(f.code == "grid-mismatch" for f in found), found
    worse = dataclasses.replace(
        spec, meta={**spec.meta, "n_minor_start": 10_000})
    assert any(f.code == "minor-boundary"
               for f in pallas_passes.check_grid_coverage(worse, "s"))


def test_kernel_specs_drive_the_launch():
    """The ragged-f geometry the launch uses comes FROM the spec: resolved
    meta must reproduce the padding/grid the kernel tests already pin."""
    spec = grouped_swiglu_kernel_spec(4, 100, 64, 96, block_c=128,
                                      block_f=128)
    m = spec.meta
    assert (m["block_c"], m["block_f"]) == (100, 96)   # clamped to dims
    assert m["pad_c"] == 0 and m["pad_f"] == 0
    assert spec.grid == (4, 1, 1)
    assert m["n_minor_start"] == 48                    # f//2 for even f
    # residency model: double-buffered streamed vmem blocks, single-counted
    # residents/scratch, SMEM maps and ANY-space arrays off the VMEM books
    fused = fused_moe_pipeline_kernel_spec(8, 16, 16, 2, 40, capacity=8)
    vmem = [b for b in fused.blocks if b.space == "vmem"]
    streamed = sum(2 * b.nbytes for b in vmem
                   if b.streamed and b.kind != "scratch")
    resident = sum(b.nbytes for b in vmem
                   if not b.streamed or b.kind == "scratch")
    assert fused.vmem_bytes() == streamed + resident
    assert fused.smem_bytes() == sum(b.nbytes for b in fused.blocks
                                     if b.space == "smem") > 0
    anys = {b.name: b for b in fused.blocks_of_space("any")}
    assert anys["x"].dma_buffers == 2 and anys["out"].dma_buffers == 1
    # the spec's staging scratch is what the kernel actually allocates:
    # 2x (block_c, d) gather tiles + accumulator + RMW stage
    names = {b.name for b in fused.blocks if b.kind == "scratch"}
    assert names == {"x_tiles", "acc_scratch", "out_stage"}


# ---------------------------------------------------------------------------
# bench schemas
# ---------------------------------------------------------------------------

def test_bench_schema_accepts_checked_in_files():
    assert bench_schema.check_bench_files(REPO) == []


def test_bench_schema_rejects_malformed(tmp_path):
    doc = json.loads((REPO / "BENCH_dispatch.json").read_text())
    assert bench_schema.validate_dispatch_bench(doc) == []
    del doc["rows"][0]["sort_us"]
    doc["smoke"] = "yes"
    errs = bench_schema.validate_dispatch_bench(doc)
    assert any("sort_us" in e for e in errs)
    assert any("smoke" in e for e in errs)
    (tmp_path / "BENCH_dispatch.json").write_text(json.dumps(doc))
    found = bench_schema.check_bench_files(tmp_path)
    assert all(f.severity == Severity.ERROR for f in found) and found


def test_bench_schema_rejects_malformed_pipeline_append():
    doc = json.loads((REPO / "BENCH_moe_pipeline.json").read_text())
    assert bench_schema.validate_pipeline_bench(doc) == []
    doc["runs"].append({"timestamp": "t", "host": {"backend": "cpu",
                                                   "devices": 1},
                        "smoke": False,
                        "rows": [{"T": 1}]})
    errs = bench_schema.validate_pipeline_bench(doc)
    assert any("buffer_us" in e for e in errs)


# ---------------------------------------------------------------------------
# baseline / runner / CLI
# ---------------------------------------------------------------------------

def test_baseline_suppression_globs(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"suppressions": [
        {"fingerprint": "pallas-vmem:*:kernel/fused_pipeline/*",
         "reason": "known"}], "hbm_bytes": {}}))
    b = Baseline.load(p)
    hit = Finding("pallas-vmem", "vmem-budget", Severity.ERROR,
                  "kernel/fused_pipeline/prod_prefill", "m")
    miss = Finding("pallas-vmem", "vmem-budget", Severity.ERROR,
                   "kernel/grouped_swiglu/prod", "m")
    assert b.suppression_for(hit) == "known"
    assert b.suppression_for(miss) is None


def test_runner_fast_matrix_clean_as_landed():
    """The acceptance bar, in-process flavor: jaxpr + spec families over
    the whole matrix (HLO compiles and engine traces run in the CI job's
    `python -m repro.lint --ci`)."""
    rep = run_lint(entries=build_entries(include_hlo=False,
                                         include_engine=False),
                   repo_root=REPO, baseline_path=REPO /
                   "lint_baseline.json")
    assert rep.exit_code == 0, rep.render(verbose=True)
    assert len(rep.entries_run) >= 10
    # the streamed rewrite removed the prod_prefill VMEM suppression — the
    # matrix must be clean with an EMPTY suppression list
    assert not rep.suppressed, [f.fingerprint for f in rep.suppressed]


def test_runner_survives_broken_entry():
    from repro.lint.registry import LintEntry

    def boom():
        raise RuntimeError("tracing exploded")

    rep = run_lint(entries=[LintEntry("broken/one", {}, boom)],
                   repo_root=REPO)
    assert rep.exit_code == 1
    assert any(f.code == "trace-error" for f in rep.findings)


def test_cli_subset_exits_zero():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--entries", "kernel/*",
         "--entries", "calib/*"],
        cwd=REPO, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": str(REPO / "src"),
             "JAX_PLATFORMS": "cpu"}, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
