"""Paged-KV serving: chunked prefill bit-exactness vs monolithic prefill,
paged-vs-contiguous engine bit-identity under mixed-length traffic, prefix
caching (bit-exact hits that skip prefill work), page churn without
retracing, the unified request API, and the deprecated KV-cache shims.

Bit-exactness here means EQUAL ARRAYS, not tolerances: the paged engine's
attention reads are trimmed to the same static reduction widths the
contiguous engines use, and exact-capacity MoE makes tokens independent of
co-batched traffic — so a float32 cache reproduces greedy tokens exactly.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as A
from repro.models import model as M
from repro.models import transformer as T
from repro.serving import (ContinuousBatchingEngine, Engine, GenerationConfig,
                           PagedEngine, Request, ServingEngine,
                           exact_moe_dist)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("mixtral-8x7b-lite")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, mults=(7, 11, 13, 17, 5, 3)):
    return [np.asarray((np.arange(L) * m) % cfg.vocab_size)
            for L, m in zip(lens, mults)]


# ---------------------------------------------------------------------------
# Chunked prefill == monolithic prefill, bitwise
# ---------------------------------------------------------------------------

def test_chunked_prefill_bitwise_equals_monolithic(served):
    """chunk_step over 5-token chunks reproduces the monolithic prefill's
    logits EXACTLY (==, not allclose) on both layouts, provided the chunk
    attention reads are trimmed (read_len) to the monolithic width — the
    softmax reduction width is part of XLA's numerics."""
    cfg, params = served
    dist = exact_moe_dist(None)
    plen, cap, chunk = 12, 20, 5
    prompt = np.asarray((np.arange(plen) * 7) % cfg.vocab_size, np.int32)
    logits_m, _ = T.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                            cfg, cache_len=cap, dist=dist,
                            cache_dtype=jnp.float32)
    logits_m = np.asarray(logits_m[0])

    def run_chunks(layout, cache, page_table=None):
        rows = []
        for start in range(0, plen, chunk):
            valid = min(chunk, plen - start)
            toks = np.zeros((1, chunk), np.int32)
            toks[0, :valid] = prompt[start:start + valid]
            lg, cache = T.chunk_step(params, jnp.asarray(toks), 1, start,
                                     valid, cache, cfg, layout=layout,
                                     page_table=page_table, read_len=plen,
                                     dist=dist)
            rows.append(np.asarray(lg[0, :valid]))
        return np.concatenate(rows, 0)

    cont = run_chunks(A.ContiguousLayout(),
                      T.init_cache(cfg, 2, cap, dtype=jnp.float32,
                                   per_slot_pos=True))
    assert (cont == logits_m).all()

    ps = 4
    ppslot = -(-cap // ps)
    pt = np.zeros((2, ppslot), np.int32)
    pt[1] = np.arange(1, 1 + ppslot)
    paged = run_chunks(A.PagedLayout(ps),
                       T.init_paged_cache(cfg, 1 + 2 * ppslot, ps, 2,
                                          dtype=jnp.float32),
                       page_table=jnp.asarray(pt))
    assert (paged == logits_m).all()


# ---------------------------------------------------------------------------
# Paged engine == contiguous engines, bitwise
# ---------------------------------------------------------------------------

def test_paged_engine_matches_continuous_mixed_traffic(served):
    """Mixed-length prompts through the paged engine (chunked prefill, page
    indirection, slot churn) produce greedy tokens bit-identical to the
    contiguous continuous-batching engine."""
    cfg, params = served
    lens = [12, 5, 9, 3, 7]
    prompts = _prompts(cfg, lens)
    gen = GenerationConfig(max_new_tokens=6)
    cont = ContinuousBatchingEngine(cfg, params, n_slots=3, max_prompt_len=16,
                                    max_new_tokens=8,
                                    cache_dtype=jnp.float32)
    ref = cont.generate(prompts, gen)
    paged = PagedEngine(cfg, params, n_slots=3, page_size=4, chunk_size=5,
                        max_prompt_len=16, max_new_tokens=8,
                        cache_dtype=jnp.float32)
    got = paged.generate(prompts, gen)
    assert [r.tokens for r in got] == [r.tokens for r in ref]
    assert paged.n_admitted == paged.n_retired == len(prompts)


def test_paged_engine_matches_synchronized_equal_lengths(served):
    """Acceptance check against the paper-baseline synchronized engine:
    equal-length prompts (its exact regime) decode to the same greedy
    tokens, while no engine step advances a prompt by more than one chunk."""
    cfg, params = served
    L, new, chunk = 12, 5, 5
    prompts = _prompts(cfg, [L] * 4)
    gen = GenerationConfig(max_new_tokens=new)
    sync = ServingEngine(cfg, params, batch_size=4, max_prompt_len=L,
                         max_new_tokens=new, exact_moe=True,
                         cache_dtype=jnp.float32)
    ref = sync.generate(prompts, gen)
    paged = PagedEngine(cfg, params, n_slots=2, page_size=4, chunk_size=chunk,
                        max_prompt_len=L, max_new_tokens=new,
                        cache_dtype=jnp.float32)
    uids = [paged.submit(p, gen) for p in prompts]
    before = 0
    while paged.step():
        # chunked-prefill bound: one step never advances prompts by more
        # than one chunk of prefill work
        assert paged.prefill_tokens - before <= chunk
        before = paged.prefill_tokens
    assert [paged.result(u).tokens for u in uids] == [r.tokens for r in ref]


# ---------------------------------------------------------------------------
# Prefix cache
# ---------------------------------------------------------------------------

def test_prefix_cache_hit_bitwise_and_skips_prefill_work(served):
    """A warm request sharing a cached prefix reuses filled pages: tokens
    stay bit-identical to the cold run while chunk invocations and prefilled
    token counts drop (the shared prefix is never recomputed)."""
    cfg, params = served
    prompts = _prompts(cfg, [12, 5, 9])
    gen = GenerationConfig(max_new_tokens=5)
    paged = PagedEngine(cfg, params, n_slots=2, page_size=4, chunk_size=5,
                        max_prompt_len=16, max_new_tokens=8,
                        cache_dtype=jnp.float32)
    cold = paged.generate(prompts, gen)
    cold_chunks, cold_tokens = paged.chunk_steps, paged.prefill_tokens
    assert paged.prefix_hits == 0
    paged.reset_stats()
    warm = paged.generate(prompts, gen)
    assert [r.tokens for r in warm] == [r.tokens for r in cold]
    assert paged.prefix_hits > 0
    assert paged.chunk_steps < cold_chunks
    assert paged.prefill_tokens < cold_tokens


def test_prefix_cache_recomputes_last_prompt_token(served):
    """A prompt whose length is an exact page multiple caps its prefix hit
    at plen-1 tokens: the final page is recomputed so the first-token logits
    exist, and outputs still match the cold run bitwise."""
    cfg, params = served
    prompt = _prompts(cfg, [8])[0]          # exactly 2 pages of 4
    gen = GenerationConfig(max_new_tokens=4)
    paged = PagedEngine(cfg, params, n_slots=1, page_size=4, chunk_size=4,
                        max_prompt_len=8, max_new_tokens=4,
                        cache_dtype=jnp.float32)
    cold = paged.generate([prompt], gen)[0].tokens
    warm_start = paged.prefill_tokens
    warm = paged.generate([prompt], gen)[0].tokens
    assert warm == cold
    # only the first page (4 tokens) may be reused; the last page holding
    # the final prompt token is prefilled again
    assert paged.prefill_tokens - warm_start == 4
    assert paged.prefix_hits == 1


def test_prefix_cache_off_never_hits(served):
    cfg, params = served
    prompt = _prompts(cfg, [8])[0]
    gen = GenerationConfig(max_new_tokens=3)
    paged = PagedEngine(cfg, params, n_slots=1, page_size=4, chunk_size=4,
                        max_prompt_len=8, max_new_tokens=4,
                        prefix_cache=False, cache_dtype=jnp.float32)
    a = paged.generate([prompt], gen)[0].tokens
    b = paged.generate([prompt], gen)[0].tokens
    assert a == b
    assert paged.prefix_hits == 0 and paged.prefix_hit_rate == 0.0


# ---------------------------------------------------------------------------
# Fixed shapes: page churn never retraces
# ---------------------------------------------------------------------------

def test_page_churn_and_prefix_reuse_never_retrace(served):
    """Slot churn, page reallocation, prefix hits, and LRU eviction all only
    change page-table VALUES — the jitted chunk-insert and decode steps
    trace exactly once."""
    cfg, params = served
    paged = PagedEngine(cfg, params, n_slots=2, page_size=4, chunk_size=5,
                        max_prompt_len=12, max_new_tokens=6,
                        n_pages=1 + 2 * 5,   # tight pool: forces eviction
                        cache_dtype=jnp.float32)
    gen = GenerationConfig(max_new_tokens=4)
    paged.generate(_prompts(cfg, [12, 7, 9, 12]), gen)
    assert (paged.chunk_traces, paged.decode_traces) == (1, 1)
    paged.generate(_prompts(cfg, [12, 9, 5]), gen)   # warm + evictions
    assert (paged.chunk_traces, paged.decode_traces) == (1, 1)


# ---------------------------------------------------------------------------
# Unified request API
# ---------------------------------------------------------------------------

def test_unified_api_across_engines(served):
    """All three engines satisfy the Engine protocol and serve the same
    submit()/step()/drain() lifecycle; drain returns submission order."""
    cfg, params = served
    prompts = _prompts(cfg, [8, 6])
    gen = GenerationConfig(max_new_tokens=3)
    kw = dict(max_prompt_len=8, max_new_tokens=4)
    engines = [ServingEngine(cfg, params, batch_size=2, **kw),
               ContinuousBatchingEngine(cfg, params, n_slots=2, **kw),
               PagedEngine(cfg, params, n_slots=2, page_size=4,
                           chunk_size=4, **kw)]
    for eng in engines:
        assert isinstance(eng, Engine)
        u0 = eng.submit(prompts[0], gen)
        u1 = eng.submit(Request(prompt=prompts[1], gen=gen))
        res = eng.drain()
        assert [r.uid for r in res] == [u0, u1]
        assert all(len(r.tokens) == 3 for r in res)
        assert eng.drain() == []            # nothing new since last drain
        assert eng.result(u0).tokens == res[0].tokens


def test_paged_timed_admission(served):
    cfg, params = served
    prompts = _prompts(cfg, [8, 8])
    arrivals = [(0.0, prompts[0], GenerationConfig(max_new_tokens=3)),
                (0.05, prompts[1], GenerationConfig(max_new_tokens=3))]
    eng = PagedEngine(cfg, params, n_slots=2, page_size=4, chunk_size=4,
                      max_prompt_len=8, max_new_tokens=4)
    res = eng.generate_timed(arrivals)
    assert [r.submitted_s for r in res] == [0.0, 0.05]
    assert all(len(r.tokens) == 3 for r in res)
    assert all(r.finished_s >= r.submitted_s for r in res)


def test_paged_rejects_oversized_and_unsupported(served):
    cfg, params = served
    eng = PagedEngine(cfg, params, n_slots=1, page_size=4, chunk_size=4,
                      max_prompt_len=8, max_new_tokens=4)
    with pytest.raises(ValueError):
        eng.submit(np.arange(9), GenerationConfig(max_new_tokens=2))
    with pytest.raises(ValueError):
        eng.submit(np.arange(4), GenerationConfig(max_new_tokens=5))


# ---------------------------------------------------------------------------
# Deprecated KV-cache shims
# ---------------------------------------------------------------------------

def test_deprecated_kv_shims_warn_and_match_layout(served):
    """init_kv_cache / build_cache_from_seq / _cache_slot warn
    DeprecationWarning and return bit-equal results to the KVCacheLayout
    replacements they delegate to."""
    del served
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(2, 6, 2, 4)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 6, 2, 4)), jnp.float32)
    layout = A.ContiguousLayout()

    with pytest.warns(DeprecationWarning):
        old = A.init_kv_cache(2, 8, 2, 4, dtype=jnp.float32)
    new = layout.init(2, 8, 2, 4, dtype=jnp.float32)
    assert all((old[x] == new[x]).all() for x in ("k", "v"))

    with pytest.warns(DeprecationWarning):
        old = A.build_cache_from_seq(k, v, 8, dtype=jnp.float32)
    new = layout.from_seq(k, v, 8, dtype=jnp.float32)
    assert all((old[x] == new[x]).all() for x in ("k", "v"))

    with pytest.warns(DeprecationWarning):
        old = A._cache_slot(jnp.asarray(11), 8, window=4)
    assert old == A.ContiguousLayout(4).slot_index(jnp.asarray(11), 8)

    # no warning on the supported surface
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        layout.init(2, 8, 2, 4, dtype=jnp.float32)
        A.kv_cache_insert(new, k[:, :1], v[:, :1], jnp.asarray(0))
