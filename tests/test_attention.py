"""Blockwise (flash-style) attention, KV caches, MLA."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as A
from repro.models import layers as L


def _qkv(rng, B=2, S=300, H=2, G=3, D=32):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, G, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    return q, k, v


@pytest.mark.parametrize("qb,kb", [(64, 48), (128, 128), (512, 1024)])
def test_blockwise_matches_plain(rng, qb, kb):
    q, k, v = _qkv(rng)
    o1 = A.blockwise_attention(q, k, v, causal=True, q_block=qb, kv_block=kb)
    o2 = A.plain_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


@pytest.mark.parametrize("window", [1, 17, 64, 299])
def test_blockwise_window(rng, window):
    q, k, v = _qkv(rng)
    o1 = A.blockwise_attention(q, k, v, causal=True, window=window,
                               q_block=64, kv_block=48)
    o2 = A.plain_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_noncausal(rng):
    q, k, v = _qkv(rng, S=100)
    o1 = A.blockwise_attention(q, k, v, causal=False, q_block=32, kv_block=32)
    o2 = A.plain_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_decode_cache_matches_full_attention(rng):
    cfg = get_config("qwen2-7b").reduced()
    from repro.models.layers import split_params
    params, _ = split_params(A.make_gqa_params(rng, cfg))
    B, S = 2, 20
    x = jax.random.normal(rng, (B, S, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = A.gqa_attention(params, x, pos, cfg, use_blockwise=False)
    cache = A.init_kv_cache(B, S + 2, cfg.n_kv_heads, cfg.resolved_head_dim,
                            dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, cache = A.gqa_decode_attention(params, x[:, t:t + 1], cache, t,
                                          cfg)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=2e-5)


def test_ring_buffer_window_decode(rng):
    cfg = dataclasses.replace(get_config("qwen2-7b").reduced(),
                              sliding_window=8)
    from repro.models.layers import split_params
    params, _ = split_params(A.make_gqa_params(rng, cfg))
    B, S, W = 2, 24, 8
    x = jax.random.normal(rng, (B, S, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = A.gqa_attention(params, x, pos, cfg, window=W,
                           use_blockwise=False)
    cache = A.init_kv_cache(B, W, cfg.n_kv_heads, cfg.resolved_head_dim,
                            dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, cache = A.gqa_decode_attention(params, x[:, t:t + 1], cache, t,
                                          cfg, window=W)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=2e-5)


def test_mla_decode_matches_prefill(rng):
    cfg = get_config("minicpm3-4b").reduced()
    from repro.models.layers import split_params
    params, _ = split_params(A.make_mla_params(rng, cfg))
    B, S = 2, 16
    x = jax.random.normal(rng, (B, S, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = A.mla_attention(params, x, pos, cfg)
    cache = A.init_mla_cache(B, S, cfg, dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, cache = A.mla_decode_attention(params, x[:, t:t + 1], cache, t,
                                          cfg)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=3e-5)


def test_mrope_sections(rng):
    """M-RoPE with equal (t,h,w) position streams == plain RoPE."""
    x = jax.random.normal(rng, (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    plain = L.apply_rope(x, pos, 1e4)
    mrope = L.apply_rope(x, pos3, 1e4, (8, 4, 4))
    np.testing.assert_allclose(np.asarray(plain), np.asarray(mrope),
                               atol=1e-5)
    # different streams give different results
    pos3b = pos3.at[1].add(5)
    mrope_b = L.apply_rope(x, pos3b, 1e4, (8, 4, 4))
    assert float(jnp.abs(mrope_b - mrope).max()) > 1e-3


def test_prefill_cache_builders(rng):
    """build_cache_from_seq ring layout must equal repeated inserts."""
    B, S, H, D, W = 1, 13, 2, 8, 8
    k = jax.random.normal(rng, (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, H, D))
    built = A.build_cache_from_seq(k, v, W, window=W, dtype=jnp.float32)
    cache = A.init_kv_cache(B, W, H, D, dtype=jnp.float32)
    for t in range(S):
        cache = A.kv_cache_insert(cache, k[:, t:t + 1], v[:, t:t + 1], t, W)
    np.testing.assert_allclose(np.asarray(built["k"]), np.asarray(cache["k"]),
                               atol=1e-6)
