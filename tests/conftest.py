# NOTE: no XLA_FLAGS here on purpose — unit/smoke tests must see the single
# real CPU device. Distributed behaviour is tested via subprocesses that set
# --xla_force_host_platform_device_count themselves (test_distributed.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def moe_cfg():
    from repro.configs import get_config
    return get_config("olmoe-lite")


@pytest.fixture(scope="session")
def moe_params(rng, moe_cfg):
    from repro.core import moe
    from repro.models.layers import split_params
    params, _ = split_params(moe.make_moe_params(rng, moe_cfg))
    return params


@pytest.fixture(scope="session")
def calib_x(rng, moe_cfg):
    from repro.data.pipeline import calibration_activations
    return calibration_activations(jax.random.fold_in(rng, 1), 96,
                                   moe_cfg.d_model)
