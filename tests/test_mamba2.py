"""Mamba2 SSD: chunked matmul form vs sequential oracle; decode handoff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import mamba2 as M
from repro.models.layers import split_params


def _ssd_inputs(rng, b=2, S=130, H=4, P=16, G=1, N=8):
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (b, S, G, N))
    C = jax.random.normal(ks[4], (b, S, G, N))
    return x, dt, A, B, C


@pytest.mark.parametrize("chunk", [16, 32, 64, 130])
def test_chunked_matches_sequential(rng, chunk):
    x, dt, A, B, C = _ssd_inputs(rng)
    y1, h1 = M.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y2, h2 = M.ssd_reference(x, dt, A, B, C)
    # f32 segsum exponentials accumulate error with the intra-chunk length
    atol = 1e-4 if chunk <= 64 else 5e-4
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=atol)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=atol)


def test_multi_group(rng):
    x, dt, A, B, C = _ssd_inputs(rng, H=4, G=2, N=8)
    y1, h1 = M.ssd_chunked(x, dt, A, B, C, chunk=32)
    y2, h2 = M.ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_forward_decode_consistency(rng):
    cfg = get_config("mamba2-370m").reduced()
    params, _ = split_params(M.make_mamba2_params(rng, cfg))
    x = jax.random.normal(rng, (2, 20, cfg.d_model)) * 0.1
    y_full = M.mamba2_forward(params, x, cfg, chunk=8)
    st = M.init_mamba_state(2, cfg)
    ys = []
    for t in range(20):
        y, st = M.mamba2_decode(params, x[:, t:t + 1], st, cfg)
        ys.append(y)
    y_dec = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dec),
                               atol=1e-5)


def test_prefill_state_handoff(rng):
    """forward(return_state) then decode == full forward."""
    cfg = get_config("mamba2-370m").reduced()
    params, _ = split_params(M.make_mamba2_params(rng, cfg))
    S = 17
    x = jax.random.normal(rng, (2, S + 3, cfg.d_model)) * 0.1
    y_all = M.mamba2_forward(params, x, cfg, chunk=8)
    y_pre, st = M.mamba2_forward(params, x[:, :S], cfg, chunk=8,
                                 return_state=True)
    st = M.MambaState(st["conv"], st["ssm"])
    np.testing.assert_allclose(np.asarray(y_all[:, :S]), np.asarray(y_pre),
                               atol=1e-5)
    for t in range(S, S + 3):
        y, st = M.mamba2_decode(params, x[:, t:t + 1], st, cfg)
        np.testing.assert_allclose(np.asarray(y_all[:, t:t + 1]),
                                   np.asarray(y), atol=1e-4)


def test_decay_stability_long_sequence(rng):
    """No NaN/Inf over long sequences (decay stays in (0,1))."""
    x, dt, A, B, C = _ssd_inputs(rng, S=1024)
    y, h = M.ssd_chunked(x, dt, A, B, C, chunk=128)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(h).all())
