"""The HLO text cost model: trip-count scaling, dot flops, collectives."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import (analyze_hlo, count_shape_instructions,
                                       shape_elems_bytes, roofline_terms)


def test_shape_parse():
    e, b = shape_elems_bytes("bf16[2,16,128]")
    assert (e, b) == (2 * 16 * 128, 2 * 16 * 128 * 2)
    e, b = shape_elems_bytes("(f32[4,4], s32[8])")
    assert b == 4 * 4 * 4 + 8 * 4


def test_scan_flops_scaled_by_trip_count():
    def f(x, w):
        def body(h, wi):
            return h @ wi, None
        h, _ = jax.lax.scan(body, x, w)
        return h

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    c = analyze_hlo(comp.as_text())
    want = 10 * 2 * 128 ** 3
    assert abs(c.flops - want) / want < 0.05, (c.flops, want)


def test_plain_matmul_flops():
    f = lambda a, b: a @ b
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    comp = jax.jit(f).lower(a, b).compile()
    c = analyze_hlo(comp.as_text())
    assert abs(c.flops - 2 * 64 * 32 * 16) / (2 * 64 * 32 * 16) < 0.01


def test_count_shape_instructions():
    """The fused-pipeline CI gate's primitive: count instructions producing
    an array of exact dims (optionally dtype), skipping parameters."""
    def f(a):
        b = jnp.broadcast_to(a[None], (4, 8, 16))     # (4, 8, 16) produced
        return b * 2.0                                # root keeps the shape

    a = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    hlo = jax.jit(f).lower(a).compile().as_text()
    n = count_shape_instructions(hlo, (4, 8, 16))
    assert n >= 1
    # dtype filter: nothing produces an s32 of that shape
    assert count_shape_instructions(hlo, (4, 8, 16), dtype="s32") == 0
    # absent shape counts zero; parameters are excluded
    assert count_shape_instructions(hlo, (3, 5, 7)) == 0
    assert count_shape_instructions(hlo, (8, 16),
                                    exclude_ops=()) >= \
        count_shape_instructions(hlo, (8, 16))


_TUPLE_HLO = """\
ENTRY %main (p: f32[8,16]) -> (f32[8,16], s32[8,16]) {
  %p = f32[8,16] parameter(0)
  %i = s32[8,16] iota(), iota_dimension=1
  ROOT %st = (f32[8,16], s32[8,16]) sort(%p, %i), dimensions={1}
}
"""


def test_count_shape_instructions_tuple_results():
    """A tuple-shaped result (sort, top_k) counts ONCE per instruction even
    when several members match, and the dtype filter selects members."""
    assert count_shape_instructions(_TUPLE_HLO, (8, 16)) == 2  # iota + sort
    assert count_shape_instructions(_TUPLE_HLO, (8, 16), dtype="f32") == 1
    assert count_shape_instructions(_TUPLE_HLO, (8, 16), dtype="s32") == 2


_FUSED_HLO = """\
%fused_computation (param_0: f32[4,8]) -> f32[4,8] {
  %param_0 = f32[4,8] parameter(0)
  %c = f32[] constant(2)
  %b = f32[4,8] broadcast(%c), dimensions={}
  ROOT %m = f32[4,8] multiply(%param_0, %b)
}

ENTRY %main (p: f32[4,8]) -> f32[4,8] {
  %p = f32[4,8] parameter(0)
  ROOT %fusion = f32[4,8] fusion(%p), kind=kLoop, calls=%fused_computation
}
"""


def test_count_shape_instructions_sees_fusion_bodies():
    """Instructions inside %fused_computation bodies count — a capacity
    buffer hidden behind XLA fusion must not evade the gate."""
    # broadcast + multiply (body) + the fusion instruction itself
    assert count_shape_instructions(_FUSED_HLO, (4, 8)) == 3
    # and on a real compile, where CPU XLA fuses the elementwise chain
    def f(a):
        return (a * 2.0 + 1.0).sum()

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((4, 8), jnp.float32)).compile().as_text()
    assert count_shape_instructions(hlo, (4, 8)) >= 2


def test_count_shape_instructions_dynamic_dims():
    """Bounded-dynamic shapes (f32[<=8,16]) must not spuriously match the
    static dims they bound — the counter is exact-static-shape only."""
    line = "  %d = f32[<=8,16] custom-call(%p), custom_call_target=\"x\"\n"
    assert count_shape_instructions(_TUPLE_HLO + line, (8, 16)) == 2


def test_roofline_terms():
    t = roofline_terms(197e12, 819e9, 200e9, 1, peak_flops=197e12,
                       hbm_bw=819e9, ici_bw=50e9)
    assert abs(t["t_compute"] - 1.0) < 1e-9
    assert abs(t["t_memory"] - 1.0) < 1e-9
    assert abs(t["t_collective"] - 1.0) < 1e-9
