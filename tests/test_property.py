"""Hypothesis property-based tests on the system's invariants.

Falls back to the deterministic randomized sweep in ``_hypothesis_compat``
when hypothesis is not installed (the CI container does not ship it)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given

    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=20,
        suppress_health_check=list(hypothesis.HealthCheck))
    hypothesis.settings.load_profile("ci")
except ImportError:
    from _hypothesis_compat import st, given, settings  # noqa: F401

from repro.core import drop, gating, load_aware, moe, partition
from repro.models.layers import split_params


@st.composite
def moe_shapes(draw):
    d = draw(st.sampled_from([16, 32, 48]))
    e = draw(st.sampled_from([4, 8, 16]))
    f = draw(st.sampled_from([8, 16, 32]))
    k = draw(st.integers(1, min(4, e)))
    p = draw(st.sampled_from([2, 4]))
    seed = draw(st.integers(0, 2 ** 16))
    renorm = draw(st.booleans())
    return d, e, f, k, p, seed, renorm


def _make(d, e, f, seed):
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(arch_id="prop", family="moe", source="", n_layers=1,
                      d_model=d, n_heads=2, n_kv_heads=2, d_ff=f,
                      vocab_size=64, n_experts=e, top_k=1, d_expert=f)
    key = jax.random.PRNGKey(seed)
    params, _ = split_params(moe.make_moe_params(key, cfg))
    x = jax.random.normal(jax.random.fold_in(key, 1), (24, d)) * 0.5
    return cfg, params, x


@given(moe_shapes())
def test_complete_transform_invariant(shapes):
    """∀ (shapes, P): complete transformation preserves outputs (Eq. 11)."""
    d, e, f, k, p, seed, renorm = shapes
    cfg, params, x = _make(d, e, f, seed)
    cfg = dataclasses.replace(cfg, top_k=k, router_norm_topk=renorm)
    y0 = moe.moe_forward_ref(params, x, cfg)
    pc = partition.complete_transform(params, p)
    cfg_p = dataclasses.replace(cfg, n_experts=e * p, top_k=k * p,
                                d_expert=f // p)
    yc = moe.moe_forward_ref(pc, x, cfg_p)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yc), atol=1e-4)


@given(moe_shapes())
def test_partial_transform_invariant(shapes):
    """∀ (shapes, P): partial transformation + Eq. 12 routing expansion
    preserves outputs (Eq. 13)."""
    d, e, f, k, p, seed, renorm = shapes
    cfg, params, x = _make(d, e, f, seed)
    cfg = dataclasses.replace(cfg, top_k=k, router_norm_topk=renorm)
    y0 = moe.moe_forward_ref(params, x, cfg)
    pp = partition.partial_transform(params, p)
    r = gating.route(x, params["wg"], k, renorm)
    pairs = drop.expand_pairs_2t(r.idx, r.combine, r.norm_score, p, -1., -1.)
    yp = moe.moe_forward_ref(pp, x, cfg, pairs=pairs)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yp), atol=1e-4)


@given(st.integers(0, 2 ** 16),
       st.floats(0.0, 0.5), st.floats(0.0, 0.4))
def test_two_t_keep_monotone(seed, t_major, gap):
    """Raising either threshold can only drop MORE pairs, and the kept set
    of 2T at (t, t) equals 1T at t."""
    t_minor = t_major + gap
    key = jax.random.PRNGKey(seed)
    s = jax.random.uniform(key, (64, 4))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (64, 4), 0, 8)
    c = jnp.ones((64, 4))
    p1 = drop.expand_pairs_2t(idx, c, s, 2, t_major, t_minor)
    p2 = drop.expand_pairs_2t(idx, c, s, 2, t_major + 0.05, t_minor + 0.05)
    assert bool((p2.keep <= p1.keep).all())


@given(st.integers(0, 2 ** 16), st.integers(2, 8),
       st.floats(0.01, 0.5))
def test_load_aware_threshold_bounds(seed, n_dev, t_max):
    """Step-down thresholds are in [0, t_max] and increase with load."""
    loads = jax.random.uniform(jax.random.PRNGKey(seed), (n_dev,),
                               minval=0.0, maxval=100.0)
    t = load_aware.step_down_thresholds(loads, t_max)
    assert float(t.min()) >= 0.0 and float(t.max()) <= t_max + 1e-6
    order = jnp.argsort(loads)
    ts = np.asarray(t)[np.asarray(order)]
    assert np.all(np.diff(ts) >= -1e-6)


@given(st.integers(0, 2 ** 16))
def test_dispatch_agrees_with_ref_property(seed):
    cfg, params, x = _make(32, 8, 16, seed)
    cfg = dataclasses.replace(cfg, top_k=2)
    y0 = moe.moe_forward_ref(params, x, cfg)
    y1 = moe.moe_forward_dispatch(params, x, cfg, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-4)


@given(st.integers(0, 2 ** 16), st.floats(0.0, 0.6), st.floats(0.0, 0.3))
def test_two_t_modes_partition_exactly(seed, t_major, gap):
    """∀ scores/thresholds: MODE_DROP / MODE_MAJOR / MODE_FULL are mutually
    exclusive AND exhaustive — every pair lands in exactly one mode, and each
    mode's membership matches its defining predicate (paper §4.2)."""
    t_minor = t_major + gap
    key = jax.random.PRNGKey(seed)
    s = jax.random.uniform(key, (96, 4))
    modes = np.asarray(drop.two_t_modes(s, t_major, t_minor))
    s = np.asarray(s)
    in_drop = modes == drop.MODE_DROP
    in_major = modes == drop.MODE_MAJOR
    in_full = modes == drop.MODE_FULL
    # exhaustive: no pair escapes the three modes
    assert np.all(in_drop | in_major | in_full)
    # mutually exclusive: exactly one mode per pair
    assert np.all(in_drop.astype(int) + in_major.astype(int)
                  + in_full.astype(int) == 1)
    # each region matches its defining predicate (strict > keeps on both
    # boundaries, matching one_t_keep — see core.drop module docstring)
    np.testing.assert_array_equal(in_full, s > t_minor)
    np.testing.assert_array_equal(in_major, (s > t_major) & (s <= t_minor))
    np.testing.assert_array_equal(in_drop, s <= t_major)
    # the expanded sub-expert keep mask realizes the modes: majors kept for
    # mode>=1, minors kept only for mode 2
    idx = jax.random.randint(jax.random.fold_in(key, 1), (96, 4), 0, 8)
    pairs = drop.expand_pairs_2t(idx, jnp.ones((96, 4)), jnp.asarray(s), 2,
                                 t_major, t_minor)
    keep = np.asarray(pairs.keep).reshape(96, 4, 2)
    np.testing.assert_array_equal(keep[:, :, 0], ~in_drop)
    np.testing.assert_array_equal(keep[:, :, 1], in_full)


@given(st.integers(0, 2 ** 16), st.sampled_from([2, 4]))
def test_one_t_drop_at_zero_keeps_everything(seed, p):
    """1T-Drop with T¹=0 never drops: normalized gating scores are strictly
    positive, so `score > 0` holds for every routed pair."""
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (64, 8))
    probs = jax.nn.softmax(logits, axis=-1)
    score, idx = jax.lax.top_k(probs, 4)
    pairs = drop.expand_pairs_1t(idx, score, score, p, 0.0)
    assert bool(pairs.keep.all())
    assert float(drop.drop_rate(pairs)) == 0.0
    assert np.all(np.asarray(pairs.modes) == drop.MODE_FULL)


@given(st.integers(0, 2 ** 16), st.floats(0.0, 0.3))
def test_drop_rate_flops_proportionality(seed, t1):
    """Paper Fig 10: the fraction of dropped token-(sub)expert computations
    equals the fraction of expert FLOPs saved (tensor-granular dropping)."""
    key = jax.random.PRNGKey(seed)
    s = jax.random.uniform(key, (128, 4))
    s = s / s.sum(-1, keepdims=True)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (128, 4), 0, 8)
    c = jnp.ones((128, 4))
    pairs = drop.expand_pairs_2t(idx, c, s, 2, t1 - 0.01, t1 + 0.01)
    dr = float(drop.drop_rate(pairs))
    fs = float(drop.flops_saved_fraction(pairs.modes))
    assert abs(dr - fs) < 1e-5
